//! Diff-aware CPU-side cache store (paper §4.3) — the LMCache-analog layer.
//!
//! Two entry classes:
//!
//! * **Dense** — a full [L, len, d] K/V copy (what every baseline stores,
//!   and what Masters are).
//! * **Mirror** — a reference to a Master plus a block-sparse K/V diff:
//!   the token-blocks (16 tokens × all layers) where the mirror's cache
//!   differs from the master's, at 10–20% of positions in All-Gather
//!   rounds. Reads return a lazy [`MirrorHandle`]; materialization is
//!   deferred to the restore path (fused or dense).
//!
//! Entries are keyed by segment content hash + a role tag, so both segment
//! donors (shared output blocks) and retained agent caches live here. When
//! a reuse plan names the Master, the store uses it; otherwise a
//! token-similarity heuristic picks the closest existing dense entry of the
//! same role class (paper's fallback).
//!
//! ## Lifecycle (pinning, re-election, capacity honesty)
//!
//! The store lives permanently at capacity in production, so its lifecycle
//! rules are load-bearing:
//!
//! * **Pinning.** A Master is pinned while any Mirror references it,
//!   tracked by an exact reverse index (`master -> {mirror keys}`), never
//!   by a bare refcount that can go stale.
//! * **Master re-election.** When a pinned Master is replaced
//!   ([`CacheStore::put_dense`] on its key) or selected for eviction, its
//!   Mirrors are *not* orphaned: every Mirror is materialized through the
//!   restore path, the cheapest one is promoted to a dense Master, and the
//!   siblings are re-diffed against it (identity-sourced, so restoring a
//!   re-homed Mirror never needs RoPE recovery). A resident Mirror's
//!   Master is therefore always resident and dense — an invariant
//!   [`CacheStore::assert_invariants`] checks.
//! * **O(1) LRU.** Recency is an intrusive doubly-linked list threaded
//!   through the entry map: `touch`, insert, and evict are O(1) per entry
//!   (the former `Vec<StoreKey>` index was O(n) per access and O(n²) per
//!   round at scale). Reading a Mirror also touches its Master, so a
//!   Master is never colder than its hottest Mirror.
//! * **Capacity honesty.** Inserts larger than `capacity_bytes` are
//!   rejected (`Err`), the byte ledger always equals the sum of resident
//!   entry sizes, and `bytes() <= capacity_bytes` holds after every
//!   operation. Lifecycle activity (evictions, promotions, re-homes,
//!   drops, rejections, hits/misses) is counted in [`StoreCounters`] and
//!   surfaced through [`StoreStats`], `EngineEvent::RoundClosed`, and the
//!   metrics layer.
//!
//! ## Storage tiers (optional cold tier, see [`tier`])
//!
//! With [`CacheStore::configure_tier`] the flat store becomes the *hot*
//! tier of a two-level hierarchy. Under capacity pressure, victims are
//! **spilled** to an on-disk cold tier instead of dropped: mirrors keep
//! their block-sparse diff form, dense payloads spill exact or quantized
//! (int8/Q4, per-block scales). Spilled keys restore transparently inside
//! [`CacheStore::get`] (a *stall restore*) or ahead of time via
//! [`CacheStore::prefetch`] when the round scheduler announces the keys
//! the next round's gather plan will read. Hot eviction switches from
//! pure LRU to KVFlow-style steps-to-next-use priority (fed by
//! [`CacheStore::hint_next_use`]), and a pinned Master victim spills with
//! its whole mirror family instead of forcing a lossy re-election. With
//! the tier off (the default) none of these paths exist and behavior is
//! bit-identical to the flat store — the golden-run digests pin that.

pub mod diff;
pub mod fault;
pub mod tier;

use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::model::ModelSpec;
use crate::runtime::{KvBuf, ModelRuntime};
pub use diff::{
    diff_blocks, diff_blocks_tol, diff_blocks_tol_masked, extract_blocks,
    gather_permuted_master, gather_permuted_master_into,
    match_blocks_by_content, match_blocks_by_segments, rediff_identity,
    AlignedDiff, BlockSparseDiff,
};
pub use fault::{FaultPlan, StoreFault};
pub use tier::{
    ColdKind, QuantFormat, QuantizedDense, SpillPayload, TierConfig,
};

/// Key of a stored cache object.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreKey {
    /// Content hash of the token segment (or full context for retained
    /// agent caches).
    pub content: u64,
    /// Disambiguates roles (segment donor vs agent retention).
    pub role: Role,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Role {
    /// KV of one shared output block (donor for PIC reuse).
    Segment,
    /// A full retained agent context cache (master or mirror).
    AgentCache { agent: usize },
}

impl Role {
    /// Role *class* equality — the partition the similarity fallback
    /// respects: segment donors never serve as similarity masters for
    /// agent-cache queries and vice versa (the agent id within
    /// `AgentCache` does not matter).
    pub fn same_class(self, other: Role) -> bool {
        matches!(
            (self, other),
            (Role::Segment, Role::Segment)
                | (Role::AgentCache { .. }, Role::AgentCache { .. })
        )
    }
}

/// Dense stored entry.
#[derive(Clone, Debug)]
pub struct DenseEntry {
    pub tokens: Vec<u32>,
    /// Positions the rows were computed at (slot i held position pos[i]).
    pub positions: Vec<i32>,
    /// [L, len, d] planes (seq == len, compact).
    pub kv: KvBuf,
}

/// Mirror entry: master reference + content-aligned block-sparse diff.
#[derive(Clone, Debug)]
pub struct MirrorEntry {
    pub master: StoreKey,
    pub tokens: Vec<u32>,
    pub positions: Vec<i32>,
    pub diff: AlignedDiff,
}

/// Resident entry. Payloads are `Arc`-backed so reads are zero-copy: a
/// fetch hands out a shared reference to the stored tensor instead of
/// cloning the full [L, len, d] planes (the engine's gather plan holds
/// many of these across one round's assembly).
#[derive(Clone, Debug)]
pub enum Entry {
    Dense(Arc<DenseEntry>),
    Mirror(Arc<MirrorEntry>),
}

/// What class of entry sits at a key — a non-counting, non-touching peek
/// (diagnostics and tests; does not perturb LRU order or hit counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryKind {
    Dense,
    Mirror,
}

/// Lazy read handle for a Mirror: everything the restore path needs without
/// materializing a dense tensor (paper: "a lightweight mirror object").
/// Owned (`Arc`-backed), so holding a handle does not borrow the store.
#[derive(Clone)]
pub struct MirrorHandle {
    pub master: Arc<DenseEntry>,
    pub mirror: Arc<MirrorEntry>,
}

/// Storage accounting for the Fig-12 compression analysis, plus the
/// cumulative lifecycle counters (copied from [`StoreCounters`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    pub dense_entries: usize,
    pub mirror_entries: usize,
    pub dense_bytes: usize,
    pub mirror_bytes: usize,
    /// Bytes mirrors would occupy if stored dense (the baseline cost).
    pub mirror_dense_equiv_bytes: usize,
    /// Dense bytes held by full agent-context caches (Masters + dense
    /// retention) as opposed to small segment donors.
    pub agent_dense_bytes: usize,
    /// Total diff blocks across mirrors (Fig-12 right panel).
    pub mirror_diff_blocks: usize,
    /// Cold-tier entries (serialized on disk; 0 when the tier is off).
    pub cold_entries: usize,
    /// Serialized cold bytes held by exact dense payloads.
    pub cold_dense_bytes: usize,
    /// Serialized cold bytes held by mirror (diff-form) payloads.
    pub cold_mirror_bytes: usize,
    /// Serialized cold bytes held by quantized dense payloads.
    pub cold_quantized_bytes: usize,
    /// Cumulative lifecycle counters since store creation.
    pub counters: StoreCounters,
}

/// Cumulative lifecycle counters (capacity-honesty observability).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Entries removed to make room (capacity pressure).
    pub evictions: u64,
    /// Master re-elections: a Mirror promoted to dense Master because its
    /// Master was evicted or replaced while still referenced.
    pub promotions: u64,
    /// Sibling Mirrors re-encoded against a newly elected Master.
    pub rehomed_mirrors: u64,
    /// Mirrors dropped because they could not be re-homed (no runtime for
    /// a position-shifted materialization, or nothing fit).
    pub dropped_mirrors: u64,
    /// Inserts refused because the entry alone exceeds capacity (or a
    /// Mirror could not fit beside its pinned Master).
    pub rejected_inserts: u64,
    /// `get` calls that found an entry.
    pub hits: u64,
    /// `get` calls that missed.
    pub misses: u64,
    /// Hot victims spilled to the cold tier instead of dropped (every
    /// spill is also counted in `evictions`, which tracks hot removals
    /// under pressure regardless of destination).
    pub spills: u64,
    /// Cold→hot restores performed inside a `get` — assembly stalled on
    /// them (the restores round-aware prefetch exists to avoid).
    pub stall_restores: u64,
    /// Cold→hot restores performed ahead of need by prefetch.
    pub prefetch_restores: u64,
    /// `get` hits served by an entry a prefetch restored — the prefetch
    /// paid off before any stall.
    pub prefetch_hits: u64,
    /// Cold-tier evictions: entries that left the hierarchy entirely to
    /// make room for newer spills.
    pub cold_evictions: u64,
    /// Cold entries dropped because they became unreadable (spill file
    /// corrupt, or their master chain broke and no re-home was possible).
    pub cold_dead_drops: u64,
    /// Hot victims that could not spill (cold tier full beside a
    /// protected master, or the write failed) and were lost outright.
    pub evicted_to_nothing: u64,
    /// Cold-tier I/O attempts that failed (injected or real), counted
    /// per attempt — a transient fault that retried cleanly still
    /// shows up here.
    pub io_errors: u64,
    /// Bounded re-attempts the degradation ladder made after an I/O
    /// error (`fault::MAX_ATTEMPTS` caps attempts per operation).
    pub retries: u64,
    /// Spill files renamed to `*.quarantine`: corrupt (checksum or
    /// decode failure), unreadable after retries, or torn `.tmp`
    /// leftovers found by crash recovery. Never served, never deleted.
    pub quarantined: u64,
    /// Cold entries re-indexed from surviving spill files by crash
    /// recovery at startup.
    pub recovered_entries: u64,
    /// Dependent cold mirrors dead-dropped because their base was lost
    /// to a *fault* (quarantine, failed write, crash) — a subset of
    /// `cold_dead_drops`, split out so fault blast radius is visible
    /// apart from capacity policy.
    pub dead_dropped_dependents: u64,
}

impl StoreStats {
    /// Whole-store compression ratio: full-dense cost / actual cost.
    pub fn compression_ratio(&self) -> f64 {
        let actual = (self.dense_bytes + self.mirror_bytes) as f64;
        let dense_equiv =
            (self.dense_bytes + self.mirror_dense_equiv_bytes) as f64;
        if actual == 0.0 {
            1.0
        } else {
            dense_equiv / actual
        }
    }

    /// The paper's Fig-12 ratio, over the sibling cache *family* only
    /// (Masters + Mirrors; segment donors excluded): what the round's N
    /// caches would cost dense, divided by master-plus-diff cost.
    pub fn family_compression_ratio(&self) -> f64 {
        let actual = (self.agent_dense_bytes + self.mirror_bytes) as f64;
        let dense_equiv = (self.agent_dense_bytes
            + self.mirror_dense_equiv_bytes) as f64;
        if actual == 0.0 {
            1.0
        } else {
            dense_equiv / actual
        }
    }

    /// Average diff blocks per mirror (Fig-12 right panel).
    pub fn avg_changed_blocks(&self) -> f64 {
        if self.mirror_entries == 0 {
            0.0
        } else {
            self.mirror_diff_blocks as f64 / self.mirror_entries as f64
        }
    }
}

impl StoreCounters {
    /// Fraction of `get` calls that hit, or None when the store was never
    /// read (a store that did nothing is not a store that hit 100%).
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.hits + self.misses;
        if total == 0 {
            None
        } else {
            Some(self.hits as f64 / total as f64)
        }
    }
}

/// Per-element tolerance when re-diffing a materialized sibling against a
/// newly elected Master: restored values may differ from the master's by
/// restore-path roundoff (same class of perturbation as the engine's
/// encode tolerance); genuine divergence is orders of magnitude larger.
const REDIFF_TOL: f32 = 5e-4;

/// One resident entry plus its intrusive LRU links and cached size.
struct Resident {
    entry: Entry,
    /// Cached `entry_bytes(entry)` — the unit of the byte ledger.
    bytes: usize,
    /// LRU neighbor toward the head (older).
    prev: Option<StoreKey>,
    /// LRU neighbor toward the tail (newer).
    next: Option<StoreKey>,
    /// Scheduler hint: the round expected to read this key next (feeds
    /// the steps-to-next-use eviction priority when the tier is on;
    /// ignored by the flat store's pure LRU).
    next_use: Option<u64>,
}

/// The store itself. `capacity_bytes` bounds resident data; inserting past
/// capacity evicts least-recently-used entries. Masters are pinned while
/// mirrors reference them — but pinning re-elects under pressure instead
/// of exempting the family from eviction forever (see module docs).
pub struct CacheStore {
    spec: ModelSpec,
    entries: HashMap<StoreKey, Resident>,
    /// LRU-oldest resident key.
    head: Option<StoreKey>,
    /// LRU-newest resident key.
    tail: Option<StoreKey>,
    capacity_bytes: usize,
    bytes: usize,
    /// Exact reverse index: master key -> keys of mirrors referencing it.
    master_refs: HashMap<StoreKey, BTreeSet<StoreKey>>,
    counters: StoreCounters,
    /// Runtime used to materialize position-shifted mirrors during master
    /// re-election; identity mirrors promote host-side without it.
    runtime: Option<(Arc<dyn ModelRuntime>, String)>,
    /// Optional cold tier (disk spill + quantization). None = flat store,
    /// the bit-pinned default.
    tier: Option<tier::ColdTier>,
    /// Monotonic round clock steps-to-next-use is measured against.
    clock_round: u64,
    /// Keys restored by prefetch and not yet read (prefetch-hit
    /// attribution; always a subset of the resident keys).
    prefetched: HashSet<StoreKey>,
    /// Cold→hot restore latencies (seconds) since the last drain.
    restore_samples: Vec<f64>,
}

fn dense_bytes(e: &DenseEntry) -> usize {
    e.kv.bytes() + e.tokens.len() * 8
}

fn mirror_bytes(m: &MirrorEntry) -> usize {
    m.diff.bytes() + m.tokens.len() * 8
}

/// Materialized snapshot of one mirror, taken before its master goes away.
struct Promotable {
    key: StoreKey,
    tokens: Vec<u32>,
    /// Compact [L, len, d] dense planes.
    kv: KvBuf,
    /// Resident cost of the mirror form (promotion prefers the cheapest).
    cost: usize,
}

impl CacheStore {
    pub fn new(spec: &ModelSpec, capacity_bytes: usize) -> Self {
        CacheStore {
            spec: spec.clone(),
            entries: HashMap::new(),
            head: None,
            tail: None,
            capacity_bytes,
            bytes: 0,
            master_refs: HashMap::new(),
            counters: StoreCounters::default(),
            runtime: None,
            tier: None,
            clock_round: 0,
            prefetched: HashSet::new(),
            restore_samples: Vec::new(),
        }
    }

    /// Enable the cold tier (creates the spill directory; with
    /// `cfg.recover`, rebuilds the cold index from surviving spill
    /// files and counts `recovered_entries` / `quarantined`). The
    /// engine calls this once at construction when a cold capacity is
    /// set.
    pub fn configure_tier(&mut self, cfg: TierConfig) -> Result<()> {
        self.tier = Some(tier::ColdTier::new(cfg, &mut self.counters)?);
        Ok(())
    }

    pub fn tier_enabled(&self) -> bool {
        self.tier.is_some()
    }

    /// Serialized bytes resident in the cold tier (0 when off).
    pub fn cold_bytes(&self) -> usize {
        self.tier.as_ref().map_or(0, |t| t.bytes())
    }

    /// Cold-tier entry count (0 when off).
    pub fn cold_len(&self) -> usize {
        self.tier.as_ref().map_or(0, |t| t.len())
    }

    /// Is `key` currently spilled cold (and not hot)?
    pub fn is_spilled(&self, key: &StoreKey) -> bool {
        self.tier.as_ref().is_some_and(|t| t.contains(key))
    }

    /// Advance the scheduler clock (monotonic). The engine calls this
    /// with every submitted round; steps-to-next-use is measured against
    /// it.
    pub fn note_round(&mut self, round: u64) {
        self.clock_round = self.clock_round.max(round);
    }

    /// Record that the round scheduler expects `key` to be read at
    /// `round` — the KVFlow-style priority feed for both tiers. A no-op
    /// for unknown keys, and when the tier is off (the flat store stays
    /// pure LRU, preserving baseline behavior bit-for-bit).
    pub fn hint_next_use(&mut self, key: &StoreKey, round: u64) {
        if self.tier.is_none() {
            return;
        }
        if let Some(r) = self.entries.get_mut(key) {
            r.next_use = Some(round);
        } else if let Some(t) = self.tier.as_mut() {
            t.hint_next_use(key, round);
        }
    }

    /// Restore the given spilled keys ahead of the round that will read
    /// them (round-aware prefetch; keys already hot or unknown are
    /// skipped). Restores triggered here never evict hot entries with a
    /// live next-use hint — a prefetch must not displace keys the same
    /// upcoming round needs. Later `get` hits on restored keys count as
    /// prefetch hits.
    pub fn prefetch(&mut self, keys: &[StoreKey]) {
        if self.tier.is_none() {
            return;
        }
        for k in keys {
            if self.entries.contains_key(k) {
                continue;
            }
            if self.tier.as_ref().is_some_and(|t| t.contains(k)) {
                self.restore_from_cold(*k, true);
            }
        }
    }

    /// Drain the cold→hot restore latency samples (seconds) recorded
    /// since the last call.
    pub fn take_restore_samples(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.restore_samples)
    }

    /// Attach the runtime master re-election uses to materialize
    /// position-shifted mirrors (identity mirrors — including every
    /// re-homed one — promote host-side without it). The engine attaches
    /// its runtime at construction.
    pub fn attach_runtime(&mut self, rt: Arc<dyn ModelRuntime>, model: String) {
        self.runtime = Some((rt, model));
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// Cumulative lifecycle counters since store creation.
    pub fn counters(&self) -> StoreCounters {
        self.counters
    }

    // -----------------------------------------------------------------
    // intrusive LRU list (O(1) touch / evict)
    // -----------------------------------------------------------------

    fn unlink(&mut self, key: StoreKey) {
        let (prev, next) = {
            let r = self.entries.get(&key).expect("unlink of missing entry");
            (r.prev, r.next)
        };
        match prev {
            Some(p) => self.entries.get_mut(&p).unwrap().next = next,
            None => self.head = next,
        }
        match next {
            Some(n) => self.entries.get_mut(&n).unwrap().prev = prev,
            None => self.tail = prev,
        }
        let r = self.entries.get_mut(&key).unwrap();
        r.prev = None;
        r.next = None;
    }

    fn push_back(&mut self, key: StoreKey) {
        match self.tail {
            Some(t) => {
                self.entries.get_mut(&t).unwrap().next = Some(key);
                let r = self.entries.get_mut(&key).unwrap();
                r.prev = Some(t);
                r.next = None;
            }
            None => {
                let r = self.entries.get_mut(&key).unwrap();
                r.prev = None;
                r.next = None;
                self.head = Some(key);
            }
        }
        self.tail = Some(key);
    }

    fn touch(&mut self, key: StoreKey) {
        if self.entries.contains_key(&key) {
            self.unlink(key);
            self.push_back(key);
        }
    }

    fn entry_bytes(e: &Entry) -> usize {
        match e {
            Entry::Dense(d) => dense_bytes(d.as_ref()),
            Entry::Mirror(m) => mirror_bytes(m.as_ref()),
        }
    }

    fn is_pinned(&self, key: &StoreKey) -> bool {
        self.master_refs.get(key).is_some_and(|s| !s.is_empty())
    }

    /// Insert a fresh resident at the MRU end, maintaining the byte ledger
    /// and the mirror reverse index. The key must not be resident.
    fn insert_resident(&mut self, key: StoreKey, entry: Entry) {
        debug_assert!(!self.entries.contains_key(&key));
        let nb = Self::entry_bytes(&entry);
        if let Entry::Mirror(m) = &entry {
            self.master_refs.entry(m.master).or_default().insert(key);
        }
        self.bytes += nb;
        self.entries.insert(
            key,
            Resident {
                entry,
                bytes: nb,
                prev: None,
                next: None,
                next_use: None,
            },
        );
        self.push_back(key);
    }

    /// Remove a resident entry (ledger + LRU + reverse index). The caller
    /// must have resolved pins first (re-election) — removing a referenced
    /// master here would orphan its mirrors.
    fn remove_resident(&mut self, key: StoreKey) -> Option<Entry> {
        if !self.entries.contains_key(&key) {
            return None;
        }
        debug_assert!(!self.is_pinned(&key), "removing a pinned master");
        self.prefetched.remove(&key);
        self.unlink(key);
        let r = self.entries.remove(&key).unwrap();
        self.bytes -= r.bytes;
        if let Entry::Mirror(m) = &r.entry {
            if let Some(set) = self.master_refs.get_mut(&m.master) {
                set.remove(&key);
                if set.is_empty() {
                    self.master_refs.remove(&m.master);
                }
            }
        }
        Some(r.entry)
    }

    // -----------------------------------------------------------------
    // master re-election
    // -----------------------------------------------------------------

    /// Re-elect a Master about to disappear (replaced or evicted) while
    /// Mirrors still reference it: materialize every Mirror via the
    /// restore path, promote the cheapest whose dense form fits capacity,
    /// and re-diff the siblings against the new Master (identity-sourced,
    /// so their future restores never need RoPE recovery). Mirrors that
    /// cannot be materialized or re-homed are dropped (counted), never
    /// left dangling. On return `old_key` is either removed (promotion
    /// happened) or unpinned (every mirror was dropped).
    fn reelect_master(&mut self, old_key: StoreKey) {
        // cold mirrors of the outgoing master re-home first, while its
        // payload is still resident dense to materialize against
        if self
            .tier
            .as_ref()
            .is_some_and(|t| !t.mirrors_of(&old_key).is_empty())
        {
            self.detach_cold_mirrors(old_key);
        }
        let Some(refs) = self.master_refs.get(&old_key) else { return };
        let mirror_keys: Vec<StoreKey> = refs.iter().copied().collect();

        // 1. materialize every mirror while the old master is resident
        let mut mats: Vec<Promotable> = Vec::new();
        let mut dropped: Vec<StoreKey> = Vec::new();
        for mk in mirror_keys {
            let made = {
                let Some(mr) = self.entries.get(&mk) else { continue };
                let Entry::Mirror(m) = &mr.entry else { continue };
                let Some(ms) = self.entries.get(&old_key) else { return };
                let Entry::Dense(md) = &ms.entry else { return };
                let rt = self
                    .runtime
                    .as_ref()
                    .map(|(r, name)| (r.as_ref(), name.as_str()));
                let handle =
                    MirrorHandle { master: md.clone(), mirror: m.clone() };
                crate::restore::materialize_for_promotion(
                    &self.spec, rt, &handle,
                )
                .ok()
                .map(|padded| Promotable {
                    key: mk,
                    tokens: m.tokens.clone(),
                    kv: padded.extract_rows(0, m.tokens.len()),
                    cost: mr.bytes,
                })
            };
            match made {
                Some(p) => mats.push(p),
                None => dropped.push(mk),
            }
        }
        for mk in dropped {
            self.remove_resident(mk);
            self.counters.dropped_mirrors += 1;
            self.counters.evictions += 1;
        }
        if mats.is_empty() {
            // every mirror failed to materialize: the master is unpinned
            // now and ordinary eviction handles it
            return;
        }

        // 2. promote the cheapest mirror whose dense form fits capacity
        mats.sort_by(|a, b| (a.cost, a.key).cmp(&(b.cost, b.key)));
        let cap = self.capacity_bytes;
        let Some(pos) = mats
            .iter()
            .position(|p| p.kv.bytes() + p.tokens.len() * 8 <= cap)
        else {
            // no candidate fits the store at all: drop them (counted) and
            // leave the now-unpinned master to ordinary eviction
            for m in mats {
                self.remove_resident(m.key);
                self.counters.dropped_mirrors += 1;
                self.counters.evictions += 1;
            }
            return;
        };
        let promoted = mats.remove(pos);

        // 3. swap the family over: mirrors out, old master out, new
        // master in (the byte ledger tracks every step)
        for m in &mats {
            self.remove_resident(m.key);
        }
        self.remove_resident(promoted.key);
        self.remove_resident(old_key);
        let plen = promoted.tokens.len();
        let mut master_padded = KvBuf::for_spec(&self.spec);
        master_padded.copy_rows_from(&promoted.kv, 0, 0, plen);
        self.insert_resident(
            promoted.key,
            Entry::Dense(Arc::new(DenseEntry {
                tokens: promoted.tokens,
                positions: (0..plen as i32).collect(),
                kv: promoted.kv,
            })),
        );
        self.counters.promotions += 1;

        // 4. re-home the siblings against the new master
        let bt = self.spec.block_tokens;
        for m in mats {
            let Promotable { key, tokens, kv, .. } = m;
            let len = tokens.len();
            let mut sib_padded = KvBuf::for_spec(&self.spec);
            sib_padded.copy_rows_from(&kv, 0, 0, len);
            let diff = rediff_identity(
                &master_padded, &sib_padded, plen, len, bt, REDIFF_TOL,
            );
            let mb = diff.bytes() + tokens.len() * 8;
            let dense_cost = kv.bytes() + tokens.len() * 8;
            let positions: Vec<i32> = (0..len as i32).collect();
            if mb < dense_cost {
                self.insert_resident(
                    key,
                    Entry::Mirror(Arc::new(MirrorEntry {
                        master: promoted.key,
                        tokens,
                        positions,
                        diff,
                    })),
                );
                self.counters.rehomed_mirrors += 1;
            } else if dense_cost <= self.capacity_bytes {
                // the sibling diverged too far from the new master for a
                // mirror to pay off: keep it dense
                self.insert_resident(
                    key,
                    Entry::Dense(Arc::new(DenseEntry { tokens, positions, kv })),
                );
                self.counters.rehomed_mirrors += 1;
            } else {
                self.counters.dropped_mirrors += 1;
                self.counters.evictions += 1;
            }
        }
    }

    // -----------------------------------------------------------------
    // eviction (and, with the tier on, spill / restore)
    // -----------------------------------------------------------------

    /// Choose the next hot eviction victim. With the tier off this is
    /// pure LRU: the head-most key other than `protect`. With the tier on
    /// it is the KVFlow-style priority: the entry with the largest
    /// steps-to-next-use at the current round clock (unhinted or stale =
    /// infinity), walking the LRU chain head→tail so ties resolve to the
    /// least-recently-used — deterministic regardless of map iteration
    /// order. With `hold_hinted` (prefetch restores) entries carrying a
    /// live hint are never victims.
    fn pick_victim(
        &self,
        protect: Option<StoreKey>,
        hold_hinted: bool,
    ) -> Option<StoreKey> {
        if self.tier.is_none() {
            let mut cur = self.head;
            while let Some(k) = cur {
                if Some(k) != protect {
                    return Some(k);
                }
                cur = self.entries.get(&k).and_then(|r| r.next);
            }
            return None;
        }
        let clock = self.clock_round;
        let mut best: Option<(u64, StoreKey)> = None;
        let mut cur = self.head;
        while let Some(k) = cur {
            let r = self.entries.get(&k).expect("LRU chain broken");
            cur = r.next;
            if Some(k) == protect {
                continue;
            }
            let steps = match r.next_use {
                Some(n) if n >= clock => n - clock,
                _ => u64::MAX,
            };
            if hold_hinted && steps != u64::MAX {
                continue;
            }
            // strict > keeps the first-encountered (LRU-oldest) on ties
            if best.map_or(true, |(bs, _)| steps > bs) {
                best = Some((steps, k));
            }
        }
        best.map(|(_, k)| k)
    }

    /// Evict hot entries until `need` more bytes fit. With the tier on,
    /// victims are spilled cold instead of dropped, and a pinned Master
    /// victim spills together with its whole mirror family (mirrors
    /// first) rather than forcing a lossy re-election — with a cold tier
    /// available nothing needs to be thrown away. With the tier off this
    /// is the original behavior: LRU drop, pinned victims re-elect.
    /// `protect` is never evicted or re-elected (the Master a Mirror
    /// insert or restore is about to reference).
    fn evict_some(
        &mut self,
        need: usize,
        protect: Option<StoreKey>,
        hold_hinted: bool,
    ) {
        // every iteration removes at least one hot entry (spills remove
        // even when the cold write fails) or resolves a pin, so the loop
        // terminates; the guard is belt-and-braces, not load-bearing
        let mut guard = 4 * self.entries.len() + 8;
        while self.bytes + need > self.capacity_bytes && guard > 0 {
            guard -= 1;
            let Some(victim) = self.pick_victim(protect, hold_hinted)
            else {
                break;
            };
            if self.tier.is_some() {
                if self.is_pinned(&victim) {
                    self.spill_family(victim);
                } else {
                    self.spill_entry(victim);
                }
            } else if self.is_pinned(&victim) {
                self.reelect_master(victim);
                // if every mirror was dropped the master is now unpinned
                // and the next iteration evicts it
            } else {
                self.remove_resident(victim);
                self.counters.evictions += 1;
            }
        }
    }

    /// [`Self::evict_some`] without the prefetch hold — the shape every
    /// put path uses.
    fn evict_for(&mut self, need: usize, protect: Option<StoreKey>) {
        self.evict_some(need, protect, false);
    }

    /// Spill one unpinned hot entry cold (or lose it, counted, when the
    /// cold tier refuses). Mirrors spill in diff form; dense entries
    /// exact or quantized per the tier config.
    fn spill_entry(&mut self, key: StoreKey) {
        let next_use = self.entries.get(&key).and_then(|r| r.next_use);
        let Some(entry) = self.remove_resident(key) else { return };
        self.counters.evictions += 1;
        let tier = self.tier.as_mut().expect("spill without a tier");
        let payload = match &entry {
            Entry::Mirror(m) => SpillPayload::Mirror(m.as_ref().clone()),
            Entry::Dense(d) => {
                if tier.quantize_dense() {
                    SpillPayload::Quantized(QuantizedDense::quantize(
                        d.as_ref(),
                        self.spec.block_tokens,
                        tier.format(),
                    ))
                } else {
                    SpillPayload::Dense(d.as_ref().clone())
                }
            }
        };
        let clock = self.clock_round;
        match tier.insert(key, &payload, next_use, clock, &mut self.counters)
        {
            Ok(()) => self.counters.spills += 1,
            Err(_) => {
                // degradation ladder, write side: the tier already
                // retried transient faults; a persistent failure
                // (capacity or I/O) drops the victim outright
                self.counters.evicted_to_nothing += 1;
                // the entry is gone for good; cold mirrors that diffed
                // against it (a dense base) are dead too
                if matches!(entry, Entry::Dense(_)) {
                    if let Some(t) = self.tier.as_mut() {
                        t.drop_dependents_of(&key, &mut self.counters);
                    }
                }
            }
        }
    }

    /// Spill a pinned Master victim with its hot mirror family: mirrors
    /// first (each spill unpins one edge), then the master itself. The
    /// cold mirrors keep referencing the master's key — readable again
    /// once the master restores (hot-dense) or directly while it sits
    /// cold in dense form.
    fn spill_family(&mut self, master_key: StoreKey) {
        let mirrors: Vec<StoreKey> = self
            .master_refs
            .get(&master_key)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default();
        for mk in mirrors {
            self.spill_entry(mk);
        }
        if !self.is_pinned(&master_key) {
            self.spill_entry(master_key);
        }
    }

    /// Restore a spilled key into the hot tier. A cold mirror needs its
    /// master readable first: already hot-dense, or itself cold in dense
    /// form (restored recursively — cold masters are never mirrors, so
    /// the recursion is depth one). Returns whether `key` ended hot.
    fn restore_from_cold(&mut self, key: StoreKey, prefetch: bool) -> bool {
        if self.entries.contains_key(&key) {
            return true;
        }
        let Some(master) = self
            .tier
            .as_ref()
            .and_then(|t| t.meta(&key).map(|m| m.master))
        else {
            return false;
        };
        if let Some(mk) = master {
            let hot_dense = matches!(
                self.entries.get(&mk).map(|r| &r.entry),
                Some(Entry::Dense(_))
            );
            if !hot_dense {
                let cold_base = self.tier.as_ref().is_some_and(|t| {
                    t.meta(&mk).is_some_and(|m| m.master.is_none())
                });
                if !(cold_base && self.restore_from_cold(mk, prefetch)) {
                    // the mirror's base is gone — dead-drop it (a
                    // dependent lost to its base's fault/loss)
                    if self.tier.as_mut().is_some_and(|t| t.remove(&key)) {
                        self.counters.cold_dead_drops += 1;
                        self.counters.dead_dropped_dependents += 1;
                    }
                    return false;
                }
            }
        }
        self.restore_one(key, prefetch)
    }

    /// Materialize one cold entry hot (its master, if any, is already
    /// hot-dense). On a fit failure the payload goes back cold instead of
    /// being lost. Counts the restore as prefetch or stall and records
    /// its latency.
    fn restore_one(&mut self, key: StoreKey, prefetch: bool) -> bool {
        let t0 = std::time::Instant::now();
        let next_use = self
            .tier
            .as_ref()
            .and_then(|t| t.meta(&key))
            .and_then(|m| m.next_use);
        let taken = match self.tier.as_mut() {
            Some(t) => t.take(&key, &mut self.counters),
            None => None,
        };
        let payload = match taken {
            Some(Ok(p)) => p,
            Some(Err(_fault)) => {
                // degradation ladder, read side: the tier already
                // retried transient I/O and quarantined the file on
                // corruption — the entry is lost; anything that diffed
                // against it (a dense base's cold mirrors) dies with
                // it, and the engine's miss path recomputes
                self.counters.cold_dead_drops += 1;
                if let Some(t) = self.tier.as_mut() {
                    t.drop_dependents_of(&key, &mut self.counters);
                }
                return false;
            }
            None => return false,
        };
        let (nb, master) = match &payload {
            SpillPayload::Dense(d) => (dense_bytes(d), None),
            SpillPayload::Quantized(q) => (q.dense_bytes(), None),
            SpillPayload::Mirror(m) => (mirror_bytes(m), Some(m.master)),
        };
        if let Some(mk) = master {
            if !matches!(
                self.entries.get(&mk).map(|r| &r.entry),
                Some(Entry::Dense(_))
            ) {
                self.counters.cold_dead_drops += 1;
                return false;
            }
        }
        self.evict_some(nb, master, prefetch);
        if nb > self.capacity_bytes
            || self.bytes + nb > self.capacity_bytes
        {
            // cannot fit right now (e.g. a prefetch refusing to displace
            // hinted entries): re-spill instead of losing the payload
            let clock = self.clock_round;
            if self
                .tier
                .as_mut()
                .expect("restore without a tier")
                .insert(key, &payload, next_use, clock, &mut self.counters)
                .is_err()
            {
                self.counters.evicted_to_nothing += 1;
            }
            return false;
        }
        let entry = match payload {
            SpillPayload::Dense(d) => Entry::Dense(Arc::new(d)),
            SpillPayload::Quantized(q) => {
                Entry::Dense(Arc::new(q.dequantize()))
            }
            SpillPayload::Mirror(m) => Entry::Mirror(Arc::new(m)),
        };
        self.insert_resident(key, entry);
        self.entries.get_mut(&key).unwrap().next_use = next_use;
        if prefetch {
            self.counters.prefetch_restores += 1;
            self.prefetched.insert(key);
        } else {
            self.counters.stall_restores += 1;
        }
        self.restore_samples.push(t0.elapsed().as_secs_f64());
        #[cfg(debug_assertions)]
        self.assert_invariants();
        true
    }

    /// Re-home the *cold* mirrors of `master_key` before its payload
    /// changes or disappears: each is materialized against the current
    /// hot master and re-spilled as a self-contained dense (or quantized)
    /// payload, keeping its next-use hint. Mirrors that cannot be
    /// materialized or re-spilled are dead-dropped (counted).
    fn detach_cold_mirrors(&mut self, master_key: StoreKey) {
        let cold: Vec<StoreKey> = self
            .tier
            .as_ref()
            .map(|t| t.mirrors_of(&master_key))
            .unwrap_or_default();
        if cold.is_empty() {
            return;
        }
        let master_rc = match self.entries.get(&master_key).map(|r| &r.entry)
        {
            Some(Entry::Dense(d)) => d.clone(),
            _ => {
                // base unreadable: nothing to materialize against
                if let Some(t) = self.tier.as_mut() {
                    t.drop_mirrors_of(&master_key, &mut self.counters);
                }
                return;
            }
        };
        for mk in cold {
            let next_use = self
                .tier
                .as_ref()
                .and_then(|t| t.meta(&mk))
                .and_then(|m| m.next_use);
            let taken = match self.tier.as_mut() {
                Some(t) => t.take(&mk, &mut self.counters),
                None => None,
            };
            let Some(Ok(SpillPayload::Mirror(m))) = taken else {
                // faulted or non-mirror payload: this dependent cannot
                // be re-homed (the tier quarantined any bad file)
                self.counters.cold_dead_drops += 1;
                continue;
            };
            let len = m.tokens.len();
            let rt = self
                .runtime
                .as_ref()
                .map(|(r, name)| (r.as_ref(), name.as_str()));
            let handle = MirrorHandle {
                master: master_rc.clone(),
                mirror: Arc::new(m),
            };
            let Ok(padded) = crate::restore::materialize_for_promotion(
                &self.spec, rt, &handle,
            ) else {
                self.counters.cold_dead_drops += 1;
                continue;
            };
            let dense = DenseEntry {
                tokens: handle.mirror.tokens.clone(),
                positions: (0..len as i32).collect(),
                kv: padded.extract_rows(0, len),
            };
            let tier = self.tier.as_mut().expect("detach without a tier");
            let payload = if tier.quantize_dense() {
                SpillPayload::Quantized(QuantizedDense::quantize(
                    &dense,
                    self.spec.block_tokens,
                    tier.format(),
                ))
            } else {
                SpillPayload::Dense(dense)
            };
            let clock = self.clock_round;
            match tier.insert(
                mk,
                &payload,
                next_use,
                clock,
                &mut self.counters,
            ) {
                Ok(()) => self.counters.rehomed_mirrors += 1,
                Err(_) => self.counters.cold_dead_drops += 1,
            }
        }
    }

    /// Remove whatever currently sits at `key` (replacement path): a
    /// pinned Master re-elects first so its Mirrors never dangle, cold
    /// mirrors of a replaced base are re-homed (or dead-dropped when the
    /// base is unreadable), and any stale cold copy of `key` is purged so
    /// it cannot shadow the incoming entry.
    fn remove_existing(&mut self, key: StoreKey) {
        if self.is_pinned(&key) {
            self.reelect_master(key);
        } else if self
            .tier
            .as_ref()
            .is_some_and(|t| !t.mirrors_of(&key).is_empty())
        {
            if matches!(
                self.entries.get(&key).map(|r| &r.entry),
                Some(Entry::Dense(_))
            ) {
                self.detach_cold_mirrors(key);
            } else if let Some(t) = self.tier.as_mut() {
                // the cold base is being replaced while unreadable (cold
                // itself): its cold mirrors cannot be re-homed
                t.drop_mirrors_of(&key, &mut self.counters);
            }
        }
        if self.entries.contains_key(&key) {
            self.remove_resident(key);
        }
        if let Some(t) = self.tier.as_mut() {
            t.remove(&key);
        }
    }

    // -----------------------------------------------------------------
    // public mutation API
    // -----------------------------------------------------------------

    /// Insert (or replace) a dense entry. Entries larger than the store's
    /// capacity are rejected (`Err`, counted) — the store never holds more
    /// than `capacity_bytes`. Replacing a Master that still has Mirrors
    /// first re-elects a new Master from them.
    pub fn put_dense(&mut self, key: StoreKey, entry: DenseEntry)
        -> Result<()>
    {
        let nb = dense_bytes(&entry);
        if nb > self.capacity_bytes {
            self.counters.rejected_inserts += 1;
            bail!(
                "dense entry of {nb} B exceeds store capacity {} B",
                self.capacity_bytes
            );
        }
        self.remove_existing(key);
        self.evict_for(nb, None);
        self.insert_resident(key, Entry::Dense(Arc::new(entry)));
        #[cfg(debug_assertions)]
        self.assert_invariants();
        Ok(())
    }

    /// Insert a mirror referencing `master` (which must be resident and
    /// dense, and distinct from `key`). Rejected (`Err`, counted) when the
    /// mirror alone exceeds capacity or cannot fit beside its pinned
    /// Master. A rejected insert may still have displaced the previous
    /// entry at `key` (replacement happens before capacity is known).
    pub fn put_mirror(&mut self, key: StoreKey, entry: MirrorEntry)
        -> Result<()>
    {
        if key == entry.master {
            return Err(anyhow!("mirror cannot reference itself"));
        }
        // a master spilled cold mid-cohort comes back hot before the
        // dense check, so the tiered store accepts exactly the mirrors
        // the flat store would
        if !self.entries.contains_key(&entry.master)
            && self
                .tier
                .as_ref()
                .is_some_and(|t| t.contains(&entry.master))
        {
            self.restore_from_cold(entry.master, false);
        }
        match self.entries.get(&entry.master).map(|r| &r.entry) {
            Some(Entry::Dense(_)) => {}
            _ => return Err(anyhow!("mirror master missing or not dense")),
        }
        let nb = mirror_bytes(&entry);
        // feasibility first: the mirror must fit beside the master it
        // pins. Checking before remove_existing avoids destroying the
        // previous entry at `key` (possibly via a full re-election) for an
        // insert that can only be rejected.
        let master_resident_bytes = self
            .entries
            .get(&entry.master)
            .map_or(0, |r| r.bytes);
        if master_resident_bytes + nb > self.capacity_bytes {
            self.counters.rejected_inserts += 1;
            bail!(
                "mirror of {nb} B cannot fit beside its pinned master \
                 ({master_resident_bytes} B) within capacity {} B",
                self.capacity_bytes
            );
        }
        self.remove_existing(key);
        self.evict_for(nb, Some(entry.master));
        if self.bytes + nb > self.capacity_bytes {
            // the protected master plus this mirror cannot coexist
            self.counters.rejected_inserts += 1;
            bail!(
                "mirror of {nb} B cannot fit beside its pinned master \
                 within {} B",
                self.capacity_bytes
            );
        }
        self.insert_resident(key, Entry::Mirror(Arc::new(entry)));
        #[cfg(debug_assertions)]
        self.assert_invariants();
        Ok(())
    }

    pub fn contains(&self, key: &StoreKey) -> bool {
        self.entries.contains_key(key)
    }

    /// Peek at the class of entry at `key` without touching LRU order or
    /// the hit/miss counters.
    pub fn kind(&self, key: &StoreKey) -> Option<EntryKind> {
        self.entries.get(key).map(|r| match r.entry {
            Entry::Dense(_) => EntryKind::Dense,
            Entry::Mirror(_) => EntryKind::Mirror,
        })
    }

    /// Fetch an entry. Dense entries come back as shared (`Arc`) payloads —
    /// zero-copy, no tensor clone — and mirrors as owned lazy handles, so
    /// the caller can hold many fetches at once (the gather plan does).
    /// Reading a mirror touches its Master too, so a Master is never
    /// LRU-colder than its hottest Mirror.
    pub fn get(&mut self, key: &StoreKey) -> Option<Fetched> {
        // tier-aware fetch: a spilled key restores on demand (a stall
        // restore — the prefetch path should have brought it back first)
        if !self.entries.contains_key(key)
            && self.tier.as_ref().is_some_and(|t| t.contains(key))
        {
            self.restore_from_cold(*key, false);
        }
        let (fetched, master_key) =
            match self.entries.get(key).map(|r| &r.entry) {
                None => {
                    self.counters.misses += 1;
                    return None;
                }
                Some(Entry::Dense(d)) => (Fetched::Dense(d.clone()), None),
                Some(Entry::Mirror(m)) => {
                    let master = match self
                        .entries
                        .get(&m.master)
                        .map(|r| &r.entry)
                    {
                        Some(Entry::Dense(d)) => d.clone(),
                        _ => unreachable!(
                            "store invariant violated: resident mirror's \
                             master is missing or not dense"
                        ),
                    };
                    (
                        Fetched::Mirror(MirrorHandle {
                            master,
                            mirror: m.clone(),
                        }),
                        Some(m.master),
                    )
                }
            };
        self.counters.hits += 1;
        if self.prefetched.remove(key) {
            self.counters.prefetch_hits += 1;
        }
        self.touch(*key);
        if let Some(mk) = master_key {
            self.touch(mk);
        }
        Some(fetched)
    }

    /// Token-similarity fallback (paper §4.3): among dense entries of the
    /// same role class as `role` and the same length, pick the one with
    /// the highest token overlap ratio; None if nothing exceeds
    /// `min_similarity`. Ties break toward the smallest key so the choice
    /// is deterministic regardless of hash-map iteration order.
    pub fn find_similar_master(
        &self,
        role: Role,
        tokens: &[u32],
        min_similarity: f64,
    ) -> Option<(StoreKey, f64)> {
        let mut best: Option<(StoreKey, f64)> = None;
        // tdlint: allow(hash_iter) -- key tie-break gives a total order
        for (k, r) in &self.entries {
            let Entry::Dense(d) = &r.entry else { continue };
            if !k.role.same_class(role) {
                continue;
            }
            if d.tokens.len() != tokens.len() {
                continue;
            }
            let same = d
                .tokens
                .iter()
                .zip(tokens)
                .filter(|(a, b)| a == b)
                .count();
            let sim = same as f64 / tokens.len().max(1) as f64;
            if sim >= min_similarity
                && best.map_or(true, |(bk, b)| {
                    sim > b || (sim == b && *k < bk)
                })
            {
                best = Some((*k, sim));
            }
        }
        best
    }

    pub fn stats(&self) -> StoreStats {
        let mut st = StoreStats::default();
        // tdlint: allow(hash_iter) -- commutative sums into counters
        for (k, r) in &self.entries {
            match &r.entry {
                Entry::Dense(d) => {
                    st.dense_entries += 1;
                    st.dense_bytes += dense_bytes(d.as_ref());
                    if matches!(k.role, Role::AgentCache { .. }) {
                        st.agent_dense_bytes += dense_bytes(d.as_ref());
                    }
                }
                Entry::Mirror(m) => {
                    st.mirror_entries += 1;
                    st.mirror_bytes += mirror_bytes(m.as_ref());
                    st.mirror_diff_blocks += m.diff.n_blocks();
                    // dense-equivalent: a full [L, len, d] K+V copy
                    st.mirror_dense_equiv_bytes += m.tokens.len()
                        * self.spec.kv_bytes_per_token()
                        + m.tokens.len() * 8;
                }
            }
        }
        if let Some(t) = &self.tier {
            for (_, m) in t.iter_meta() {
                st.cold_entries += 1;
                match m.kind {
                    ColdKind::Dense => st.cold_dense_bytes += m.bytes,
                    ColdKind::Mirror => st.cold_mirror_bytes += m.bytes,
                    ColdKind::Quantized => {
                        st.cold_quantized_bytes += m.bytes
                    }
                }
            }
        }
        st.counters = self.counters;
        st
    }

    /// Panic unless every structural invariant holds: the byte ledger
    /// equals the sum of resident entry sizes and stays within capacity,
    /// the LRU chain is a consistent doubly-linked list covering exactly
    /// the resident keys, every reverse-index edge matches a resident
    /// Mirror, and every resident Mirror's Master is resident and dense.
    /// Cheap enough for tests and debug builds (O(n)); called after every
    /// mutation in debug builds.
    // tdlint: allow(hash_iter) -- read-only assertions, no output or state
    pub fn assert_invariants(&self) {
        // byte ledger
        let mut sum = 0usize;
        for (k, r) in &self.entries {
            let eb = Self::entry_bytes(&r.entry);
            assert_eq!(r.bytes, eb, "stale cached size for {k:?}");
            sum += eb;
        }
        assert_eq!(self.bytes, sum, "byte ledger out of balance");
        assert!(
            self.bytes <= self.capacity_bytes,
            "over capacity: {} > {}",
            self.bytes,
            self.capacity_bytes
        );
        // LRU chain
        let mut seen = 0usize;
        let mut prev: Option<StoreKey> = None;
        let mut cur = self.head;
        while let Some(k) = cur {
            let r = self
                .entries
                .get(&k)
                .expect("LRU chain references a missing entry");
            assert_eq!(r.prev, prev, "broken prev link at {k:?}");
            prev = Some(k);
            cur = r.next;
            seen += 1;
            assert!(seen <= self.entries.len(), "LRU chain cycle");
        }
        assert_eq!(self.tail, prev, "tail does not end the LRU chain");
        assert_eq!(
            seen,
            self.entries.len(),
            "LRU chain length != resident entries"
        );
        // mirror/master topology
        for (k, r) in &self.entries {
            if let Entry::Mirror(m) = &r.entry {
                let set = self
                    .master_refs
                    .get(&m.master)
                    .expect("resident mirror missing from reverse index");
                assert!(set.contains(k), "reverse index misses {k:?}");
                match self.entries.get(&m.master).map(|r| &r.entry) {
                    Some(Entry::Dense(_)) => {}
                    _ => panic!(
                        "mirror {k:?} dangling: master {:?} not resident \
                         dense",
                        m.master
                    ),
                }
            }
        }
        for (mk, set) in &self.master_refs {
            assert!(!set.is_empty(), "empty reverse-index set for {mk:?}");
            assert!(
                matches!(
                    self.entries.get(mk).map(|r| &r.entry),
                    Some(Entry::Dense(_))
                ),
                "reverse index names a non-dense master {mk:?}"
            );
            for s in set {
                match self.entries.get(s).map(|r| &r.entry) {
                    Some(Entry::Mirror(m)) => assert_eq!(m.master, *mk),
                    _ => panic!("reverse-index edge {mk:?} -> {s:?} stale"),
                }
            }
        }
        // cold tier: its own ledger, plus hot/cold disjointness and the
        // cold-mirror base rule (master hot-dense or itself cold base)
        if let Some(t) = &self.tier {
            t.assert_invariants();
            for (k, m) in t.iter_meta() {
                assert!(
                    !self.entries.contains_key(k),
                    "key {k:?} resident hot and cold at once"
                );
                if let Some(mk) = m.master {
                    let hot_dense = matches!(
                        self.entries.get(&mk).map(|r| &r.entry),
                        Some(Entry::Dense(_))
                    );
                    let cold_base = t
                        .meta(&mk)
                        .is_some_and(|b| b.master.is_none());
                    assert!(
                        hot_dense || cold_base,
                        "cold mirror {k:?} dangling: master {mk:?} is \
                         neither hot-dense nor a cold base"
                    );
                }
            }
        }
        for k in &self.prefetched {
            assert!(
                self.entries.contains_key(k),
                "prefetched set names a non-resident key {k:?}"
            );
        }
    }
}

/// The result of a fetch: shared, owned views (holding one does not
/// borrow the store, and cloning one never copies tensor data).
#[derive(Clone)]
pub enum Fetched {
    Dense(Arc<DenseEntry>),
    Mirror(MirrorHandle),
}

/// Wrap a positionally-aligned BlockSparseDiff into an AlignedDiff with the
/// identity source mapping (mirror block i sourced from master block i,
/// positions unchanged). Used where master and mirror share slot layout.
pub fn identity_aligned(
    corrections: BlockSparseDiff,
    n_blocks: usize,
    len: usize,
) -> AlignedDiff {
    AlignedDiff {
        src_block: (0..n_blocks as i32).collect(),
        src_pos: (0..len as i32).collect(),
        corrections,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            vocab: 512,
            max_seq: 64,
            block_tokens: 16,
            check_layer: 1,
            rope_theta: 10000.0,
        }
    }

    fn dense(spec: &ModelSpec, len: usize, fill: f32) -> DenseEntry {
        let mut kv = KvBuf::zeroed(spec.n_layers, len, spec.d_model);
        kv.k.iter_mut().for_each(|x| *x = fill);
        kv.v.iter_mut().for_each(|x| *x = -fill);
        DenseEntry {
            tokens: (0..len as u32).map(|i| 4 + (i + fill as u32)).collect(),
            positions: (0..len as i32).collect(),
            kv,
        }
    }

    fn key(c: u64) -> StoreKey {
        StoreKey { content: c, role: Role::Segment }
    }

    fn akey(c: u64, agent: usize) -> StoreKey {
        StoreKey { content: c, role: Role::AgentCache { agent } }
    }

    /// A mirror of `master` differing in one block, with the differing
    /// element's value derived from `salt` (so promoted data is checkable).
    fn mirror_of(
        sp: &ModelSpec,
        st: &mut CacheStore,
        master: StoreKey,
        salt: f32,
    ) -> MirrorEntry {
        let (mkv, toks) = match st.get(&master) {
            Some(Fetched::Dense(d)) => (d.kv.clone(), d.tokens.clone()),
            _ => panic!("master not dense"),
        };
        let len = toks.len();
        let mut mk = mkv.clone();
        let o = mk.off(0, 17.min(len - 1));
        mk.k[o] += salt;
        let d = diff_blocks(&mkv, &mk, len, sp.block_tokens);
        let d = identity_aligned(d, len.div_ceil(sp.block_tokens), len);
        MirrorEntry {
            master,
            tokens: toks,
            positions: (0..len as i32).collect(),
            diff: d,
        }
    }

    #[test]
    fn put_get_dense() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 20);
        st.put_dense(key(1), dense(&sp, 32, 1.0)).unwrap();
        match st.get(&key(1)) {
            Some(Fetched::Dense(d)) => assert_eq!(d.tokens.len(), 32),
            _ => panic!("expected dense"),
        }
        assert!(st.get(&key(2)).is_none());
        let c = st.counters();
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn mirror_requires_master_and_counts_compression() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 22);
        let master = dense(&sp, 64, 1.0);
        // mirror differs in one 16-token block
        let mut mk = master.kv.clone();
        let o = mk.off(0, 17);
        mk.k[o] += 1.0;
        let d = diff_blocks(&master.kv, &mk, 64, sp.block_tokens);
        assert_eq!(d.block_ids, vec![1]);
        let d = identity_aligned(d, 4, 64);

        st.put_dense(key(1), master).unwrap();
        let m = MirrorEntry {
            master: key(1),
            tokens: (0..64).map(|i| 4 + i as u32).collect(),
            positions: (0..64).collect(),
            diff: d,
        };
        assert!(st.put_mirror(key(2), m.clone()).is_ok());
        // mirror against a missing master fails
        let mut bad = m.clone();
        bad.master = key(99);
        assert!(st.put_mirror(key(3), bad).is_err());
        // a mirror referencing itself fails
        let mut selfish = m;
        selfish.master = key(4);
        assert!(st.put_mirror(key(4), selfish).is_err());

        let stats = st.stats();
        assert_eq!(stats.dense_entries, 1);
        assert_eq!(stats.mirror_entries, 1);
        assert!(stats.compression_ratio() > 1.5,
                "ratio={}", stats.compression_ratio());
        st.assert_invariants();
    }

    #[test]
    fn eviction_promotes_pinned_master_instead_of_orphaning() {
        let sp = spec();
        // capacity fits ~2 dense entries of len 64
        let one = dense(&sp, 64, 1.0);
        let cap = (one.kv.bytes() + 64 * 8) * 2 + 64;
        let mut st = CacheStore::new(&sp, cap);
        st.put_dense(key(1), dense(&sp, 64, 1.0)).unwrap();
        let m = mirror_of(&sp, &mut st, key(1), 2.0);
        let mirror_kv_expected = {
            let mut kv = dense(&sp, 64, 1.0).kv;
            let o = kv.off(0, 17);
            kv.k[o] += 2.0;
            kv
        };
        st.put_mirror(key(2), m).unwrap();
        // a new dense entry forces eviction; the LRU-oldest entry is the
        // pinned master -> its mirror is promoted to a dense master
        // (lossless for the mirror) and the old master goes
        st.put_dense(key(3), dense(&sp, 64, 3.0)).unwrap();
        assert!(!st.contains(&key(1)), "old master re-elected away");
        assert!(st.contains(&key(3)));
        match st.get(&key(2)) {
            Some(Fetched::Dense(d)) => {
                assert_eq!(d.kv, mirror_kv_expected,
                           "promotion preserves the mirror's data");
            }
            _ => panic!("promoted mirror must be resident dense"),
        }
        let c = st.counters();
        assert_eq!(c.promotions, 1);
        st.assert_invariants();
        // the promoted master is unpinned: ordinary LRU fodder now. The
        // data check above touched key(2), so key(3) is the LRU victim.
        st.put_dense(key(4), dense(&sp, 64, 4.0)).unwrap();
        assert!(st.contains(&key(2)) && st.contains(&key(4)));
        assert!(!st.contains(&key(3)), "unpinned LRU victim evicted");
        assert!(st.counters().evictions > 0);
    }

    #[test]
    fn replacing_a_pinned_master_reelects_and_rehomes_siblings() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 22);
        st.put_dense(akey(1, 0), dense(&sp, 64, 1.0)).unwrap();
        let m2 = mirror_of(&sp, &mut st, akey(1, 0), 2.0);
        let m3 = mirror_of(&sp, &mut st, akey(1, 0), 3.0);
        st.put_mirror(akey(2, 1), m2).unwrap();
        st.put_mirror(akey(3, 2), m3).unwrap();
        // overwrite the master key with unrelated content: both mirrors
        // must survive — one promoted, one re-homed against it
        st.put_dense(akey(1, 0), dense(&sp, 32, 9.0)).unwrap();
        st.assert_invariants();
        let c = st.counters();
        assert_eq!(c.promotions, 1);
        assert_eq!(c.rehomed_mirrors, 1);
        // regression (the orphaning bug): get on a resident mirror never
        // returns None
        for k in [akey(2, 1), akey(3, 2)] {
            assert!(st.contains(&k));
            assert!(st.get(&k).is_some(), "{k:?} orphaned");
        }
        // the cheapest mirror (tie broken by key order) got promoted
        assert!(matches!(st.get(&akey(2, 1)), Some(Fetched::Dense(_))));
        // the sibling's data survived the re-home bit-exactly (identity
        // mirrors promote and re-diff without roundoff)
        let expect3 = {
            let mut kv = dense(&sp, 64, 1.0).kv;
            let o = kv.off(0, 17);
            kv.k[o] += 3.0;
            kv
        };
        match st.get(&akey(3, 2)) {
            Some(Fetched::Mirror(h)) => {
                let mut rebuilt = h.master.kv.clone();
                h.mirror.diff.corrections.apply_to(&mut rebuilt);
                assert_eq!(rebuilt, expect3);
            }
            Some(Fetched::Dense(d)) => assert_eq!(d.kv, expect3),
            None => panic!("sibling lost"),
        }
    }

    #[test]
    fn oversize_inserts_are_rejected_capacity_honest() {
        let sp = spec();
        let small = dense(&sp, 16, 1.0);
        let cap = small.kv.bytes() + 16 * 8 + 32;
        let mut st = CacheStore::new(&sp, cap);
        assert!(st.put_dense(key(1), dense(&sp, 64, 1.0)).is_err());
        assert_eq!(st.bytes(), 0);
        assert_eq!(st.counters().rejected_inserts, 1);
        st.put_dense(key(2), small).unwrap();
        assert!(st.bytes() <= cap);
        st.assert_invariants();
    }

    #[test]
    fn mirror_that_cannot_fit_beside_its_master_is_rejected() {
        let sp = spec();
        // size the capacity to master + mirror minus a sliver: the mirror
        // alone fits, but not beside the master it must pin
        let master = dense(&sp, 32, 1.0);
        let master_bytes = master.kv.bytes() + 32 * 8;
        let mut probe = CacheStore::new(&sp, 1 << 22);
        probe.put_dense(key(1), master.clone()).unwrap();
        let m = mirror_of(&sp, &mut probe, key(1), 1.5);
        let mb = m.diff.bytes() + m.tokens.len() * 8;
        assert!(mb < master_bytes, "premise: mirror cheaper than master");
        let cap = master_bytes + mb - 16;
        let mut st = CacheStore::new(&sp, cap);
        st.put_dense(key(1), master).unwrap();
        let err = st.put_mirror(key(2), m);
        assert!(err.is_err(), "must reject, never overcommit");
        assert!(st.contains(&key(1)), "protected master survives");
        assert!(!st.contains(&key(2)));
        assert!(st.bytes() <= cap);
        assert_eq!(st.counters().rejected_inserts, 1);
        st.assert_invariants();
    }

    #[test]
    fn similarity_fallback_finds_closest() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 22);
        st.put_dense(key(1), dense(&sp, 32, 1.0)).unwrap();
        st.put_dense(key(2), dense(&sp, 32, 2.0)).unwrap();
        // query equals entry-2's tokens except 2 positions
        let mut q: Vec<u32> = (0..32).map(|i| 4 + (i + 2)).collect();
        q[0] = 999;
        q[1] = 998;
        let (k, sim) =
            st.find_similar_master(Role::Segment, &q, 0.8).unwrap();
        assert_eq!(k, key(2));
        assert!((sim - 30.0 / 32.0).abs() < 1e-9);
        assert!(st.find_similar_master(Role::Segment, &q, 0.99).is_none());
    }

    #[test]
    fn similarity_fallback_respects_role_class() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 22);
        // identical tokens under both role classes
        st.put_dense(key(7), dense(&sp, 32, 1.0)).unwrap();
        st.put_dense(akey(8, 3), dense(&sp, 32, 1.0)).unwrap();
        let q = dense(&sp, 32, 1.0).tokens;
        // an AgentCache query must never elect a Segment donor
        let (k, sim) = st
            .find_similar_master(Role::AgentCache { agent: 9 }, &q, 0.5)
            .unwrap();
        assert_eq!(k, akey(8, 3));
        assert!((sim - 1.0).abs() < 1e-9);
        let (k, _) =
            st.find_similar_master(Role::Segment, &q, 0.5).unwrap();
        assert_eq!(k, key(7));
    }

    #[test]
    fn lru_order_survives_touch_churn() {
        // O(1) list bookkeeping: interleaved touches and inserts keep the
        // chain consistent and evict in true recency order
        let sp = spec();
        let one = dense(&sp, 16, 1.0);
        let eb = one.kv.bytes() + 16 * 8;
        let mut st = CacheStore::new(&sp, eb * 3 + 16);
        st.put_dense(key(1), dense(&sp, 16, 1.0)).unwrap();
        st.put_dense(key(2), dense(&sp, 16, 2.0)).unwrap();
        st.put_dense(key(3), dense(&sp, 16, 3.0)).unwrap();
        // touch 1 so 2 becomes the LRU victim
        assert!(st.get(&key(1)).is_some());
        st.put_dense(key(4), dense(&sp, 16, 4.0)).unwrap();
        assert!(st.contains(&key(1)) && st.contains(&key(3)));
        assert!(!st.contains(&key(2)), "true LRU victim evicted");
        st.assert_invariants();
    }

    // -----------------------------------------------------------------
    // storage tier
    // -----------------------------------------------------------------

    fn tier_store(
        sp: &ModelSpec,
        hot: usize,
        cold: usize,
        quantize: bool,
        name: &str,
    ) -> CacheStore {
        let mut st = CacheStore::new(sp, hot);
        let dir = std::env::temp_dir().join(format!(
            "td-store-tier-{}-{name}",
            std::process::id()
        ));
        st.configure_tier(TierConfig {
            cold_bytes: cold,
            spill_dir: dir,
            quantize,
            format: QuantFormat::Int8,
            fault_plan: None,
            recover: false,
        })
        .unwrap();
        st
    }

    /// A dense entry with per-element varied values (quantization needs
    /// non-constant planes to exercise the scales).
    fn vdense(sp: &ModelSpec, len: usize) -> DenseEntry {
        let mut d = dense(sp, len, 1.0);
        for (i, x) in d.kv.k.iter_mut().enumerate() {
            *x = (i as f32 * 0.37).sin() * 3.0;
        }
        for (i, x) in d.kv.v.iter_mut().enumerate() {
            *x = (i as f32 * 0.11).cos() * 2.0;
        }
        d
    }

    #[test]
    fn spilled_dense_restores_bitwise_on_get() {
        let sp = spec();
        let one = dense(&sp, 16, 1.0);
        let eb = dense_bytes(&one);
        let mut st = tier_store(&sp, eb + 64, 1 << 20, false, "dense-rt");
        st.put_dense(key(1), one.clone()).unwrap();
        st.put_dense(key(2), dense(&sp, 16, 2.0)).unwrap();
        assert!(!st.contains(&key(1)), "capacity forces a spill");
        assert!(st.is_spilled(&key(1)));
        let stats = st.stats();
        assert_eq!(stats.cold_entries, 1);
        assert!(stats.cold_dense_bytes > 0);
        match st.get(&key(1)) {
            Some(Fetched::Dense(d)) => {
                assert_eq!(d.kv, one.kv, "restore must be bitwise");
                assert_eq!(d.tokens, one.tokens);
                assert_eq!(d.positions, one.positions);
            }
            _ => panic!("expected restored dense"),
        }
        let c = st.counters();
        assert_eq!(c.stall_restores, 1);
        assert_eq!(c.spills, 2, "key2 spilled to make room for the restore");
        assert_eq!(c.evicted_to_nothing, 0);
        st.assert_invariants();
    }

    #[test]
    fn spilled_mirror_round_trips_bitwise_with_master_chain() {
        let sp = spec();
        let master = dense(&sp, 64, 1.0);
        let mb = dense_bytes(&master);
        let mut probe = CacheStore::new(&sp, 1 << 22);
        probe.put_dense(key(1), master.clone()).unwrap();
        let m = mirror_of(&sp, &mut probe, key(1), 2.0);
        let mm = mirror_bytes(&m);

        let mut st =
            tier_store(&sp, mb + mm + 128, 1 << 20, false, "mirror-rt");
        st.put_dense(key(1), master.clone()).unwrap();
        st.put_mirror(key(2), m.clone()).unwrap();
        // the unhinted mirror is the priority victim; the master follows
        // it cold once its pin clears, and both restore on demand
        st.note_round(1);
        st.hint_next_use(&key(1), 1);
        st.put_dense(key(3), dense(&sp, 32, 3.0)).unwrap();
        assert!(st.is_spilled(&key(2)), "mirror spilled under pressure");
        match st.get(&key(2)) {
            Some(Fetched::Mirror(h)) => {
                assert_eq!(h.master.kv, master.kv, "master bitwise");
                assert_eq!(h.mirror.diff, m.diff, "diff bitwise");
                assert_eq!(h.mirror.tokens, m.tokens);
                assert_eq!(h.mirror.positions, m.positions);
            }
            _ => panic!("expected restored mirror"),
        }
        let c = st.counters();
        assert!(c.stall_restores >= 1);
        assert_eq!(c.cold_dead_drops, 0);
        st.assert_invariants();
    }

    #[test]
    fn cold_tier_full_victim_drops_to_nothing_counted() {
        let sp = spec();
        let one = dense(&sp, 16, 1.0);
        let eb = dense_bytes(&one);
        // a cold tier too small for any entry: the hot victim has
        // nowhere to spill and is dropped outright — counted, and the
        // key simply misses afterwards (the caller recomputes)
        let mut st = tier_store(&sp, eb + 64, 64, false, "cold-full");
        st.put_dense(key(1), one).unwrap();
        st.put_dense(key(2), dense(&sp, 16, 2.0)).unwrap();
        let c = st.counters();
        assert_eq!(c.evicted_to_nothing, 1, "victim dropped, not spilled");
        assert_eq!(c.spills, 0);
        assert!(!st.contains(&key(1)));
        assert!(!st.is_spilled(&key(1)));
        assert!(st.get(&key(1)).is_none(), "dropped key must miss");
        assert!(st.contains(&key(2)));
        st.assert_invariants();
    }

    #[test]
    fn unreadable_cold_entries_dead_drop_never_panic() {
        let sp = spec();
        let master = dense(&sp, 64, 1.0);
        let mb = dense_bytes(&master);
        let mut probe = CacheStore::new(&sp, 1 << 22);
        probe.put_dense(key(1), master.clone()).unwrap();
        let m = mirror_of(&sp, &mut probe, key(1), 2.0);
        let mm = mirror_bytes(&m);
        drop(probe);

        let name = "dead-chain";
        let mut st = tier_store(&sp, mb + mm + 128, 1 << 20, false, name);
        st.put_dense(key(1), master).unwrap();
        st.put_mirror(key(2), m).unwrap();
        // push both cold, then corrupt every spill file on disk: the
        // master restore under key(2)'s get fails its checksum, so the
        // chain dead-drops and the get degrades to a clean miss
        st.put_dense(key(3), dense(&sp, 48, 3.0)).unwrap();
        st.put_dense(key(4), dense(&sp, 48, 4.0)).unwrap();
        assert!(st.is_spilled(&key(1)) && st.is_spilled(&key(2)));
        let dir = std::env::temp_dir().join(format!(
            "td-store-tier-{}-{name}",
            std::process::id()
        ));
        for f in std::fs::read_dir(&dir).unwrap().flatten() {
            let p = f.path();
            if p.extension().is_some_and(|x| x == "tdm") {
                let mut b = std::fs::read(&p).unwrap();
                let mid = b.len() / 2;
                b[mid] ^= 0xff;
                std::fs::write(&p, &b).unwrap();
            }
        }
        assert!(st.get(&key(2)).is_none(), "corrupt chain must miss");
        assert!(st.get(&key(1)).is_none(), "corrupt master must miss");
        let c = st.counters();
        assert!(c.cold_dead_drops >= 2, "both cold entries dead: {c:?}");
        assert!(c.quarantined >= 1, "corrupt files quarantined");
        assert!(!st.is_spilled(&key(1)) && !st.is_spilled(&key(2)));
        st.assert_invariants();
        drop(st);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quantized_spill_restores_within_tolerance() {
        let sp = spec();
        let one = vdense(&sp, 16);
        let eb = dense_bytes(&one);
        let mut st = tier_store(&sp, eb + 64, 1 << 20, true, "quant");
        st.put_dense(key(1), one.clone()).unwrap();
        st.put_dense(key(2), dense(&sp, 16, 2.0)).unwrap();
        assert!(st.is_spilled(&key(1)));
        let stats = st.stats();
        assert!(stats.cold_quantized_bytes > 0);
        assert!(
            stats.cold_quantized_bytes < eb,
            "quantized payload must compress: {} vs {eb}",
            stats.cold_quantized_bytes
        );
        let maxabs = one
            .kv
            .k
            .iter()
            .chain(one.kv.v.iter())
            .fold(0f32, |a, x| a.max(x.abs()));
        // int8: error <= scale/2, scale <= global maxabs / 127
        let bound = maxabs * 0.5 / 127.0 + 1e-6;
        match st.get(&key(1)) {
            Some(Fetched::Dense(d)) => {
                assert_eq!(d.tokens, one.tokens, "tokens are lossless");
                let worst = d
                    .kv
                    .k
                    .iter()
                    .zip(&one.kv.k)
                    .chain(d.kv.v.iter().zip(&one.kv.v))
                    .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
                assert!(
                    worst <= bound,
                    "dequantized error {worst} exceeds bound {bound}"
                );
            }
            _ => panic!("expected restored dense"),
        }
        st.assert_invariants();
    }

    #[test]
    fn master_reelection_rehomes_spilled_mirrors() {
        let sp = spec();
        let master = dense(&sp, 64, 1.0);
        let mb = dense_bytes(&master);
        let mut probe = CacheStore::new(&sp, 1 << 22);
        probe.put_dense(key(1), master.clone()).unwrap();
        let m2 = mirror_of(&sp, &mut probe, key(1), 2.0);
        let m3 = mirror_of(&sp, &mut probe, key(1), 3.0);
        let mm = mirror_bytes(&m2);

        let mut st = tier_store(
            &sp,
            mb + 2 * mm + 64,
            1 << 20,
            false,
            "reelect",
        );
        st.put_dense(key(1), master.clone()).unwrap();
        st.put_mirror(key(2), m2).unwrap();
        st.put_mirror(key(3), m3).unwrap();
        // pressure spills exactly the unhinted mirror key3 cold
        st.note_round(1);
        st.hint_next_use(&key(1), 1);
        st.hint_next_use(&key(2), 1);
        st.put_dense(key(4), dense(&sp, 16, 4.0)).unwrap();
        assert!(st.is_spilled(&key(3)), "cold mirror precondition");
        // replacing the master re-elects: the cold mirror must re-home
        // (self-contained) before the old payload disappears
        st.put_dense(key(1), dense(&sp, 64, 9.0)).unwrap();
        let c = st.counters();
        assert!(c.rehomed_mirrors >= 1, "cold mirror re-homed");
        assert_eq!(c.promotions, 1, "hot mirror promoted to master");
        assert_eq!(c.cold_dead_drops, 0);
        // the re-homed mirror reads back as the exact old master + salt
        let mut expected = master.kv.clone();
        let o = expected.off(0, 17);
        expected.k[o] += 3.0;
        match st.get(&key(3)) {
            Some(Fetched::Dense(d)) => {
                assert_eq!(d.kv, expected, "re-homed payload bitwise")
            }
            _ => panic!("expected self-contained re-homed entry"),
        }
        st.assert_invariants();
    }

    #[test]
    fn prefetch_restores_and_hits_are_counted() {
        let sp = spec();
        let one = dense(&sp, 16, 1.0);
        let eb = dense_bytes(&one);
        let mut st =
            tier_store(&sp, eb + 64, 1 << 20, false, "prefetch");
        st.put_dense(key(1), one).unwrap();
        st.put_dense(key(2), dense(&sp, 16, 2.0)).unwrap();
        assert!(st.is_spilled(&key(1)));
        st.prefetch(&[key(1)]);
        assert!(st.contains(&key(1)), "prefetch restored the key");
        let c = st.counters();
        assert_eq!(c.prefetch_restores, 1);
        assert!(st.get(&key(1)).is_some());
        let c = st.counters();
        assert_eq!(c.prefetch_hits, 1);
        assert_eq!(c.stall_restores, 0);

        // a prefetch never displaces entries hinted for the current
        // rounds: it fails gracefully and the payload stays cold
        st.note_round(5);
        st.hint_next_use(&key(1), 5);
        st.prefetch(&[key(2)]);
        assert!(st.contains(&key(1)), "hinted entry held hot");
        assert!(st.is_spilled(&key(2)), "payload re-spilled, not lost");
        assert_eq!(st.counters().evicted_to_nothing, 0);
        st.assert_invariants();
    }

    #[test]
    fn priority_eviction_prefers_unhinted() {
        let sp = spec();
        let one = dense(&sp, 16, 1.0);
        let eb = dense_bytes(&one);
        let mut st =
            tier_store(&sp, 3 * eb + 64, 1 << 20, false, "prio");
        st.put_dense(key(1), dense(&sp, 16, 1.0)).unwrap();
        st.put_dense(key(2), dense(&sp, 16, 2.0)).unwrap();
        st.put_dense(key(3), dense(&sp, 16, 3.0)).unwrap();
        st.note_round(2);
        st.hint_next_use(&key(1), 2);
        st.put_dense(key(4), dense(&sp, 16, 4.0)).unwrap();
        // LRU would evict key1; the hint overrides recency, so the
        // oldest *unhinted* entry spills instead
        assert!(st.contains(&key(1)), "hinted entry survives");
        assert!(st.is_spilled(&key(2)), "oldest unhinted entry spilled");
        assert!(st.contains(&key(3)) && st.contains(&key(4)));
        st.assert_invariants();
    }
}
