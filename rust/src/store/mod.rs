//! Diff-aware CPU-side cache store (paper §4.3) — the LMCache-analog layer.
//!
//! Two entry classes:
//!
//! * **Dense** — a full [L, len, d] K/V copy (what every baseline stores,
//!   and what Masters are).
//! * **Mirror** — a reference to a Master plus a block-sparse K/V diff:
//!   the token-blocks (16 tokens × all layers) where the mirror's cache
//!   differs from the master's, at 10–20% of positions in All-Gather
//!   rounds. Reads return a lazy [`MirrorHandle`]; materialization is
//!   deferred to the restore path (fused or dense).
//!
//! Entries are keyed by segment content hash + a role tag, so both segment
//! donors (shared output blocks) and retained agent caches live here. When
//! a reuse plan names the Master, the store uses it; otherwise a
//! token-similarity heuristic picks the closest existing dense entry
//! (paper's fallback).

pub mod diff;

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::model::ModelSpec;
use crate::runtime::KvBuf;
pub use diff::{
    diff_blocks, diff_blocks_tol, extract_blocks, gather_permuted_master,
    match_blocks_by_content, match_blocks_by_segments, AlignedDiff,
    BlockSparseDiff,
};

/// Key of a stored cache object.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreKey {
    /// Content hash of the token segment (or full context for retained
    /// agent caches).
    pub content: u64,
    /// Disambiguates roles (segment donor vs agent retention).
    pub role: Role,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Role {
    /// KV of one shared output block (donor for PIC reuse).
    Segment,
    /// A full retained agent context cache (master or mirror).
    AgentCache { agent: usize },
}

/// Dense stored entry.
#[derive(Clone, Debug)]
pub struct DenseEntry {
    pub tokens: Vec<u32>,
    /// Positions the rows were computed at (slot i held position pos[i]).
    pub positions: Vec<i32>,
    /// [L, len, d] planes (seq == len, compact).
    pub kv: KvBuf,
}

/// Mirror entry: master reference + content-aligned block-sparse diff.
#[derive(Clone, Debug)]
pub struct MirrorEntry {
    pub master: StoreKey,
    pub tokens: Vec<u32>,
    pub positions: Vec<i32>,
    pub diff: AlignedDiff,
}

#[derive(Clone, Debug)]
pub enum Entry {
    Dense(DenseEntry),
    Mirror(MirrorEntry),
}

/// Lazy read handle for a Mirror: everything the restore path needs without
/// materializing a dense tensor (paper: "a lightweight mirror object").
pub struct MirrorHandle<'a> {
    pub master: &'a DenseEntry,
    pub mirror: &'a MirrorEntry,
}

/// Storage accounting for the Fig-12 compression analysis.
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    pub dense_entries: usize,
    pub mirror_entries: usize,
    pub dense_bytes: usize,
    pub mirror_bytes: usize,
    /// Bytes mirrors would occupy if stored dense (the baseline cost).
    pub mirror_dense_equiv_bytes: usize,
    /// Dense bytes held by full agent-context caches (Masters + dense
    /// retention) as opposed to small segment donors.
    pub agent_dense_bytes: usize,
    /// Total diff blocks across mirrors (Fig-12 right panel).
    pub mirror_diff_blocks: usize,
}

impl StoreStats {
    /// Whole-store compression ratio: full-dense cost / actual cost.
    pub fn compression_ratio(&self) -> f64 {
        let actual = (self.dense_bytes + self.mirror_bytes) as f64;
        let dense_equiv =
            (self.dense_bytes + self.mirror_dense_equiv_bytes) as f64;
        if actual == 0.0 {
            1.0
        } else {
            dense_equiv / actual
        }
    }

    /// The paper's Fig-12 ratio, over the sibling cache *family* only
    /// (Masters + Mirrors; segment donors excluded): what the round's N
    /// caches would cost dense, divided by master-plus-diff cost.
    pub fn family_compression_ratio(&self) -> f64 {
        let actual = (self.agent_dense_bytes + self.mirror_bytes) as f64;
        let dense_equiv = (self.agent_dense_bytes
            + self.mirror_dense_equiv_bytes) as f64;
        if actual == 0.0 {
            1.0
        } else {
            dense_equiv / actual
        }
    }

    /// Average diff blocks per mirror (Fig-12 right panel).
    pub fn avg_changed_blocks(&self) -> f64 {
        if self.mirror_entries == 0 {
            0.0
        } else {
            self.mirror_diff_blocks as f64 / self.mirror_entries as f64
        }
    }
}

/// The store itself. `capacity_bytes` bounds resident data; inserting past
/// capacity evicts least-recently-used entries (masters are pinned while
/// mirrors reference them).
pub struct CacheStore {
    spec: ModelSpec,
    entries: HashMap<StoreKey, Entry>,
    lru: Vec<StoreKey>, // front = oldest
    capacity_bytes: usize,
    bytes: usize,
    /// master key -> number of mirrors referencing it
    master_refs: HashMap<StoreKey, usize>,
    pub evictions: u64,
}

fn dense_bytes(e: &DenseEntry) -> usize {
    e.kv.bytes() + e.tokens.len() * 8
}

fn mirror_bytes(m: &MirrorEntry) -> usize {
    m.diff.bytes() + m.tokens.len() * 8
}

impl CacheStore {
    pub fn new(spec: &ModelSpec, capacity_bytes: usize) -> Self {
        CacheStore {
            spec: spec.clone(),
            entries: HashMap::new(),
            lru: Vec::new(),
            capacity_bytes,
            bytes: 0,
            master_refs: HashMap::new(),
            evictions: 0,
        }
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    fn touch(&mut self, key: StoreKey) {
        if let Some(p) = self.lru.iter().position(|k| *k == key) {
            self.lru.remove(p);
        }
        self.lru.push(key);
    }

    fn entry_bytes(e: &Entry) -> usize {
        match e {
            Entry::Dense(d) => dense_bytes(d),
            Entry::Mirror(m) => mirror_bytes(m),
        }
    }

    fn evict_for(&mut self, need: usize) {
        let mut i = 0;
        while self.bytes + need > self.capacity_bytes && i < self.lru.len() {
            let key = self.lru[i];
            let pinned = self.master_refs.get(&key).copied().unwrap_or(0) > 0;
            if pinned {
                i += 1;
                continue;
            }
            self.lru.remove(i);
            if let Some(e) = self.entries.remove(&key) {
                self.bytes -= Self::entry_bytes(&e);
                if let Entry::Mirror(m) = &e {
                    if let Some(rc) = self.master_refs.get_mut(&m.master) {
                        *rc = rc.saturating_sub(1);
                    }
                }
                self.evictions += 1;
            }
        }
    }

    fn remove_existing(&mut self, key: StoreKey) {
        if let Some(old) = self.entries.remove(&key) {
            self.bytes -= Self::entry_bytes(&old);
            if let Entry::Mirror(m) = &old {
                if let Some(rc) = self.master_refs.get_mut(&m.master) {
                    *rc = rc.saturating_sub(1);
                }
            }
            if let Some(p) = self.lru.iter().position(|k| *k == key) {
                self.lru.remove(p);
            }
        }
    }

    /// Insert (or replace) a dense entry.
    pub fn put_dense(&mut self, key: StoreKey, entry: DenseEntry) {
        self.remove_existing(key);
        let nb = dense_bytes(&entry);
        self.evict_for(nb);
        self.bytes += nb;
        self.entries.insert(key, Entry::Dense(entry));
        self.touch(key);
    }

    /// Insert a mirror referencing `master` (which must be dense).
    pub fn put_mirror(&mut self, key: StoreKey, entry: MirrorEntry)
        -> Result<()>
    {
        match self.entries.get(&entry.master) {
            Some(Entry::Dense(_)) => {}
            _ => return Err(anyhow!("mirror master missing or not dense")),
        }
        self.remove_existing(key);
        let nb = mirror_bytes(&entry);
        self.evict_for(nb);
        self.bytes += nb;
        *self.master_refs.entry(entry.master).or_insert(0) += 1;
        self.entries.insert(key, Entry::Mirror(entry));
        self.touch(key);
        Ok(())
    }

    pub fn contains(&self, key: &StoreKey) -> bool {
        self.entries.contains_key(key)
    }

    /// Fetch an entry. Dense entries come back directly; mirrors come back
    /// as lazy handles.
    pub fn get(&mut self, key: &StoreKey) -> Option<Fetched<'_>> {
        if !self.entries.contains_key(key) {
            return None;
        }
        self.touch(*key);
        match self.entries.get(key) {
            Some(Entry::Dense(d)) => Some(Fetched::Dense(d)),
            Some(Entry::Mirror(m)) => {
                let master = match self.entries.get(&m.master) {
                    Some(Entry::Dense(d)) => d,
                    _ => return None, // master evicted (shouldn't happen)
                };
                Some(Fetched::Mirror(MirrorHandle { master, mirror: m }))
            }
            None => None,
        }
    }

    /// Token-similarity fallback (paper §4.3): among dense entries of the
    /// same role class and length, pick the one with the highest token
    /// overlap ratio; None if nothing exceeds `min_similarity`.
    pub fn find_similar_master(
        &self,
        tokens: &[u32],
        min_similarity: f64,
    ) -> Option<(StoreKey, f64)> {
        let mut best: Option<(StoreKey, f64)> = None;
        for (k, e) in &self.entries {
            let Entry::Dense(d) = e else { continue };
            if d.tokens.len() != tokens.len() {
                continue;
            }
            let same = d
                .tokens
                .iter()
                .zip(tokens)
                .filter(|(a, b)| a == b)
                .count();
            let sim = same as f64 / tokens.len().max(1) as f64;
            if sim >= min_similarity
                && best.map_or(true, |(_, b)| sim > b)
            {
                best = Some((*k, sim));
            }
        }
        best
    }

    pub fn stats(&self) -> StoreStats {
        let mut st = StoreStats::default();
        for (k, e) in &self.entries {
            match e {
                Entry::Dense(d) => {
                    st.dense_entries += 1;
                    st.dense_bytes += dense_bytes(d);
                    if matches!(k.role, Role::AgentCache { .. }) {
                        st.agent_dense_bytes += dense_bytes(d);
                    }
                }
                Entry::Mirror(m) => {
                    st.mirror_entries += 1;
                    st.mirror_bytes += mirror_bytes(m);
                    st.mirror_diff_blocks += m.diff.n_blocks();
                    // dense-equivalent: a full [L, len, d] K+V copy
                    st.mirror_dense_equiv_bytes += m.tokens.len()
                        * self.spec.kv_bytes_per_token()
                        + m.tokens.len() * 8;
                }
            }
        }
        st
    }
}

pub enum Fetched<'a> {
    Dense(&'a DenseEntry),
    Mirror(MirrorHandle<'a>),
}

/// Wrap a positionally-aligned BlockSparseDiff into an AlignedDiff with the
/// identity source mapping (mirror block i sourced from master block i,
/// positions unchanged). Used where master and mirror share slot layout.
pub fn identity_aligned(
    corrections: BlockSparseDiff,
    n_blocks: usize,
    len: usize,
) -> AlignedDiff {
    AlignedDiff {
        src_block: (0..n_blocks as i32).collect(),
        src_pos: (0..len as i32).collect(),
        corrections,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            vocab: 512,
            max_seq: 64,
            block_tokens: 16,
            check_layer: 1,
            rope_theta: 10000.0,
        }
    }

    fn dense(spec: &ModelSpec, len: usize, fill: f32) -> DenseEntry {
        let mut kv = KvBuf::zeroed(spec.n_layers, len, spec.d_model);
        kv.k.iter_mut().for_each(|x| *x = fill);
        kv.v.iter_mut().for_each(|x| *x = -fill);
        DenseEntry {
            tokens: (0..len as u32).map(|i| 4 + (i + fill as u32)).collect(),
            positions: (0..len as i32).collect(),
            kv,
        }
    }

    fn key(c: u64) -> StoreKey {
        StoreKey { content: c, role: Role::Segment }
    }

    #[test]
    fn put_get_dense() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 20);
        st.put_dense(key(1), dense(&sp, 32, 1.0));
        match st.get(&key(1)) {
            Some(Fetched::Dense(d)) => assert_eq!(d.tokens.len(), 32),
            _ => panic!("expected dense"),
        }
        assert!(st.get(&key(2)).is_none());
    }

    #[test]
    fn mirror_requires_master_and_counts_compression() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 22);
        let master = dense(&sp, 64, 1.0);
        // mirror differs in one 16-token block
        let mut mk = master.kv.clone();
        let o = mk.off(0, 17);
        mk.k[o] += 1.0;
        let d = diff_blocks(&master.kv, &mk, 64, sp.block_tokens);
        assert_eq!(d.block_ids, vec![1]);
        let d = identity_aligned(d, 4, 64);

        st.put_dense(key(1), master);
        let m = MirrorEntry {
            master: key(1),
            tokens: (0..64).map(|i| 4 + i as u32).collect(),
            positions: (0..64).collect(),
            diff: d,
        };
        assert!(st
            .put_mirror(key(2), m.clone())
            .is_ok());
        // mirror against a missing master fails
        let mut bad = m;
        bad.master = key(99);
        assert!(st.put_mirror(key(3), bad).is_err());

        let stats = st.stats();
        assert_eq!(stats.dense_entries, 1);
        assert_eq!(stats.mirror_entries, 1);
        assert!(stats.compression_ratio() > 1.5,
                "ratio={}", stats.compression_ratio());
    }

    #[test]
    fn lru_eviction_pins_referenced_masters() {
        let sp = spec();
        // capacity fits ~2 dense entries of len 64
        let one = dense(&sp, 64, 1.0);
        let cap = (one.kv.bytes() + 64 * 8) * 2 + 64;
        let mut st = CacheStore::new(&sp, cap);
        st.put_dense(key(1), dense(&sp, 64, 1.0));
        let mut mk = dense(&sp, 64, 1.0).kv;
        let o = mk.off(0, 0);
        mk.k[o] += 2.0;
        let diff = identity_aligned(
            diff_blocks(&st_master_kv(&st), &mk, 64, sp.block_tokens),
            4,
            64,
        );
        st.put_mirror(
            key(2),
            MirrorEntry {
                master: key(1),
                tokens: (0..64).map(|i| i as u32).collect(),
                positions: (0..64).collect(),
                diff,
            },
        )
        .unwrap();
        // a new dense entry forces eviction: the mirror (unpinned) must go
        // first even though the master is older in LRU order
        st.put_dense(key(3), dense(&sp, 64, 3.0));
        assert!(st.contains(&key(1)), "pinned master survives");
        assert!(!st.contains(&key(2)), "mirror evicted first");
        assert!(st.evictions > 0);
        // with the mirror gone the pin is released; the master is now
        // ordinary LRU fodder
        st.put_dense(key(4), dense(&sp, 64, 4.0));
        assert!(!st.contains(&key(1)), "unpinned master evictable");
        assert!(st.contains(&key(3)) && st.contains(&key(4)));
    }

    fn st_master_kv(st: &CacheStore) -> KvBuf {
        match st.entries.get(&key(1)) {
            Some(Entry::Dense(d)) => d.kv.clone(),
            _ => panic!(),
        }
    }

    #[test]
    fn similarity_fallback_finds_closest() {
        let sp = spec();
        let mut st = CacheStore::new(&sp, 1 << 22);
        st.put_dense(key(1), dense(&sp, 32, 1.0));
        st.put_dense(key(2), dense(&sp, 32, 2.0));
        // query equals entry-2's tokens except 2 positions
        let mut q: Vec<u32> = (0..32).map(|i| 4 + (i + 2)).collect();
        q[0] = 999;
        q[1] = 998;
        let (k, sim) = st.find_similar_master(&q, 0.8).unwrap();
        assert_eq!(k, key(2));
        assert!((sim - 30.0 / 32.0).abs() < 1e-9);
        assert!(st.find_similar_master(&q, 0.99).is_none());
    }
}
