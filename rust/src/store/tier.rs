//! Cold storage tier behind [`CacheStore`](super::CacheStore): disk
//! spill, payload quantization, and steps-to-next-use metadata (the
//! MT-APC-style hierarchy; ROADMAP "tiered storage" item).
//!
//! Under hot-capacity pressure the store no longer drops entries — it
//! *spills* them here. Mirrors keep their block-sparse
//! [`AlignedDiff`](super::AlignedDiff) form (already 11–17x smaller than
//! dense), and dense payloads are optionally quantized — int8 or Q4 with
//! one f32 scale per (layer, token-block) per plane — before
//! serialization. Every cold entry is one little-endian flat file
//! (`spill-<seq>.tdm`, magic `TDM1`) under the configured spill
//! directory; f32 values travel as raw bit patterns, so an unquantized
//! spill → restore round trip is **bitwise**, and
//! `EngineBuilder::quantize(false)` is the equivalence baseline (same
//! discipline as `gather_plan` / `collective_encode`).
//!
//! The tier records, per cold entry, the round scheduler's *next-use
//! hint* (which round will read the key next). Cold eviction — the only
//! lossy step in the hierarchy — removes the entry with the largest
//! steps-to-next-use (unhinted or stale = infinity), the same
//! KVFlow-style priority the hot tier uses under pressure, with ties
//! broken toward the oldest spill sequence number so the choice is
//! deterministic regardless of hash-map iteration order. Evicting a cold
//! master dead-drops its cold mirrors (their diffs have no base left);
//! both losses are counted, never silent.

use std::collections::{BTreeSet, HashMap};
use std::fs;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::diff::{wire, AlignedDiff};
use super::{DenseEntry, MirrorEntry, Role, StoreCounters, StoreKey};
use crate::runtime::KvBuf;

/// Quantization format for spilled dense payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantFormat {
    /// 8-bit symmetric: scale = maxabs/127 per (layer, block) per plane.
    Int8,
    /// 4-bit symmetric, two values per byte: scale = maxabs/7.
    Q4,
}

impl QuantFormat {
    fn qmax(self) -> f32 {
        match self {
            QuantFormat::Int8 => 127.0,
            QuantFormat::Q4 => 7.0,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            QuantFormat::Int8 => "int8",
            QuantFormat::Q4 => "q4",
        }
    }
}

impl std::str::FromStr for QuantFormat {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "int8" => QuantFormat::Int8,
            "q4" => QuantFormat::Q4,
            other => bail!("unknown quant format {other:?} (int8 | q4)"),
        })
    }
}

/// Cold-tier configuration (`CacheStore::configure_tier`, fed from
/// `EngineBuilder::cold_tier` / `spill_dir` / `quantize` / `quant_format`).
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// Serialized-byte capacity of the cold tier.
    pub cold_bytes: usize,
    /// Directory the spill files live in (created on configure; files and
    /// the directory are removed on drop — but only when empty, never
    /// recursively, since the path is user-supplied).
    pub spill_dir: PathBuf,
    /// Quantize dense payloads on spill. `false` keeps spills exact and
    /// is the bitwise-equivalence baseline.
    pub quantize: bool,
    pub format: QuantFormat,
}

// ---------------------------------------------------------------------
// quantization
// ---------------------------------------------------------------------

/// A dense entry quantized per (layer, token-block): one f32 scale per
/// block per plane, values one byte each (int8) or two per byte (Q4).
/// The packed value stream is in `KvBuf` element order, so quantize and
/// dequantize walk the planes identically.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedDense {
    pub format: QuantFormat,
    pub layers: usize,
    pub len: usize,
    pub d: usize,
    pub block_tokens: usize,
    pub tokens: Vec<u32>,
    pub positions: Vec<i32>,
    /// Per (layer, block) K-plane scales, layer-major.
    pub k_scales: Vec<f32>,
    pub v_scales: Vec<f32>,
    pub k_q: Vec<u8>,
    pub v_q: Vec<u8>,
}

// tdlint: allow(panic_path) -- plane is layers*len*d by construction
fn quantize_plane(
    xs: &[f32],
    layers: usize,
    len: usize,
    d: usize,
    block_tokens: usize,
    format: QuantFormat,
) -> (Vec<f32>, Vec<u8>) {
    let nb = len.div_ceil(block_tokens).max(1);
    let qmax = format.qmax();
    let mut scales = Vec::with_capacity(layers * nb);
    let mut qi: Vec<i8> = Vec::with_capacity(xs.len());
    for l in 0..layers {
        for b in 0..nb {
            let lo = (l * len + b * block_tokens) * d;
            let hi = (l * len + len.min((b + 1) * block_tokens)) * d;
            let maxabs = xs[lo..hi]
                .iter()
                .fold(0.0f32, |m, x| m.max(x.abs()));
            // an all-zero block quantizes through a unit scale (0/1 = 0)
            let scale = if maxabs == 0.0 { 1.0 } else { maxabs / qmax };
            scales.push(scale);
            for &x in &xs[lo..hi] {
                qi.push((x / scale).round().clamp(-qmax, qmax) as i8);
            }
        }
    }
    let packed = match format {
        QuantFormat::Int8 => qi.iter().map(|&v| v as u8).collect(),
        QuantFormat::Q4 => {
            // nibble-pack pairs over the whole plane stream (values are in
            // [-7, 7]; stored biased by +8 so a nibble is never sign-lossy)
            let mut out = Vec::with_capacity(qi.len().div_ceil(2));
            for pair in qi.chunks(2) {
                let lo = (pair[0] + 8) as u8 & 0x0f;
                let hi = if pair.len() == 2 {
                    ((pair[1] + 8) as u8 & 0x0f) << 4
                } else {
                    0
                };
                out.push(lo | hi);
            }
            out
        }
    };
    (scales, packed)
}

// tdlint: allow(panic_path) -- packed/scales sized by the quantizer
fn dequantize_plane(
    packed: &[u8],
    scales: &[f32],
    layers: usize,
    len: usize,
    d: usize,
    block_tokens: usize,
    format: QuantFormat,
) -> Vec<f32> {
    let nb = len.div_ceil(block_tokens).max(1);
    let mut out = Vec::with_capacity(layers * len * d);
    let unpack = |i: usize| -> i8 {
        match format {
            QuantFormat::Int8 => packed[i] as i8,
            QuantFormat::Q4 => {
                let byte = packed[i / 2];
                let nib = if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
                nib as i8 - 8
            }
        }
    };
    let mut i = 0usize;
    for l in 0..layers {
        for s in 0..len {
            let scale = scales[l * nb + s / block_tokens];
            for _ in 0..d {
                out.push(unpack(i) as f32 * scale);
                i += 1;
            }
        }
    }
    out
}

impl QuantizedDense {
    /// Quantize a dense entry per (layer, token-block). Per-element error
    /// of the round trip is bounded by `scale / 2` (scale = block
    /// maxabs / qmax).
    pub fn quantize(
        e: &DenseEntry,
        block_tokens: usize,
        format: QuantFormat,
    ) -> Self {
        let kv = &e.kv;
        let (layers, len, d) = (kv.layers, kv.seq, kv.d);
        let (k_scales, k_q) =
            quantize_plane(&kv.k, layers, len, d, block_tokens, format);
        let (v_scales, v_q) =
            quantize_plane(&kv.v, layers, len, d, block_tokens, format);
        QuantizedDense {
            format,
            layers,
            len,
            d,
            block_tokens,
            tokens: e.tokens.clone(),
            positions: e.positions.clone(),
            k_scales,
            v_scales,
            k_q,
            v_q,
        }
    }

    /// Reconstruct the dense entry (lossy: per-element error <= scale/2).
    pub fn dequantize(&self) -> DenseEntry {
        let mut kv = KvBuf::zeroed(self.layers, self.len, self.d);
        kv.k = dequantize_plane(
            &self.k_q,
            &self.k_scales,
            self.layers,
            self.len,
            self.d,
            self.block_tokens,
            self.format,
        );
        kv.v = dequantize_plane(
            &self.v_q,
            &self.v_scales,
            self.layers,
            self.len,
            self.d,
            self.block_tokens,
            self.format,
        );
        DenseEntry {
            tokens: self.tokens.clone(),
            positions: self.positions.clone(),
            kv,
        }
    }

    /// Bytes of the reconstructed dense form — the hot-tier cost a
    /// restore pays (the store's accounting unit for dense entries).
    pub fn dense_bytes(&self) -> usize {
        2 * self.layers * self.len * self.d * 4 + self.tokens.len() * 8
    }

    /// In-memory bytes of the quantized form itself.
    pub fn bytes(&self) -> usize {
        self.k_q.len()
            + self.v_q.len()
            + (self.k_scales.len() + self.v_scales.len()) * 4
            + self.tokens.len() * 4
            + self.positions.len() * 4
    }
}

// ---------------------------------------------------------------------
// spill payloads + on-disk codec
// ---------------------------------------------------------------------

/// One payload spilled to the cold tier.
#[derive(Clone, Debug)]
pub enum SpillPayload {
    /// Exact dense entry (the `quantize(false)` path — bitwise round
    /// trip).
    Dense(DenseEntry),
    /// Block-sparse mirror (always exact; restoring it needs its master
    /// resident dense, so the restore path re-heats masters first).
    Mirror(MirrorEntry),
    /// Quantized dense entry (lossy; dequantized on restore).
    Quantized(QuantizedDense),
}

impl SpillPayload {
    pub fn kind(&self) -> ColdKind {
        match self {
            SpillPayload::Dense(_) => ColdKind::Dense,
            SpillPayload::Mirror(_) => ColdKind::Mirror,
            SpillPayload::Quantized(_) => ColdKind::Quantized,
        }
    }

    /// Master key a mirror payload depends on (None for dense forms).
    pub fn master(&self) -> Option<StoreKey> {
        match self {
            SpillPayload::Mirror(m) => Some(m.master),
            _ => None,
        }
    }
}

const MAGIC: &[u8; 4] = b"TDM1";

fn put_key(out: &mut Vec<u8>, key: &StoreKey) {
    wire::put_u64(out, key.content);
    match key.role {
        Role::Segment => {
            wire::put_u8(out, 0);
            wire::put_u64(out, 0);
        }
        Role::AgentCache { agent } => {
            wire::put_u8(out, 1);
            wire::put_u64(out, agent as u64);
        }
    }
}

fn read_key(r: &mut wire::Reader) -> Result<StoreKey> {
    let content = r.u64()?;
    let tag = r.u8()?;
    let agent = r.u64()? as usize;
    let role = match tag {
        0 => Role::Segment,
        1 => Role::AgentCache { agent },
        other => bail!("unknown role tag {other} in spill payload"),
    };
    Ok(StoreKey { content, role })
}

fn put_dense_payload(out: &mut Vec<u8>, e: &DenseEntry) {
    wire::put_u32s(out, &e.tokens);
    wire::put_i32s(out, &e.positions);
    wire::put_u64(out, e.kv.layers as u64);
    wire::put_u64(out, e.kv.seq as u64);
    wire::put_u64(out, e.kv.d as u64);
    wire::put_f32s(out, &e.kv.k);
    wire::put_f32s(out, &e.kv.v);
}

fn read_dense_payload(r: &mut wire::Reader) -> Result<DenseEntry> {
    let tokens = r.u32s()?;
    let positions = r.i32s()?;
    let layers = r.u64()? as usize;
    let seq = r.u64()? as usize;
    let d = r.u64()? as usize;
    let k = r.f32s()?;
    let v = r.f32s()?;
    if k.len() != layers * seq * d || v.len() != k.len() {
        bail!("dense spill plane size mismatch");
    }
    let mut kv = KvBuf::zeroed(layers, seq, d);
    kv.k = k;
    kv.v = v;
    Ok(DenseEntry { tokens, positions, kv })
}

/// Serialize `(key, payload)` into one flat spill-file image.
pub fn encode_payload(key: &StoreKey, p: &SpillPayload) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    wire::put_u8(
        &mut out,
        match p {
            SpillPayload::Dense(_) => 0,
            SpillPayload::Mirror(_) => 1,
            SpillPayload::Quantized(_) => 2,
        },
    );
    put_key(&mut out, key);
    match p {
        SpillPayload::Dense(e) => put_dense_payload(&mut out, e),
        SpillPayload::Mirror(m) => {
            put_key(&mut out, &m.master);
            wire::put_u32s(&mut out, &m.tokens);
            wire::put_i32s(&mut out, &m.positions);
            m.diff.write_le(&mut out);
        }
        SpillPayload::Quantized(q) => {
            wire::put_u8(
                &mut out,
                match q.format {
                    QuantFormat::Int8 => 0,
                    QuantFormat::Q4 => 1,
                },
            );
            wire::put_u64(&mut out, q.layers as u64);
            wire::put_u64(&mut out, q.len as u64);
            wire::put_u64(&mut out, q.d as u64);
            wire::put_u64(&mut out, q.block_tokens as u64);
            wire::put_u32s(&mut out, &q.tokens);
            wire::put_i32s(&mut out, &q.positions);
            wire::put_f32s(&mut out, &q.k_scales);
            wire::put_f32s(&mut out, &q.v_scales);
            wire::put_bytes(&mut out, &q.k_q);
            wire::put_bytes(&mut out, &q.v_q);
        }
    }
    out
}

/// Decode one spill-file image back to `(key, payload)`.
pub fn decode_payload(buf: &[u8]) -> Result<(StoreKey, SpillPayload)> {
    let mut r = wire::Reader::new(buf);
    if r.raw(4)? != MAGIC {
        bail!("bad spill magic (expected TDM1)");
    }
    let kind = r.u8()?;
    let key = read_key(&mut r)?;
    let payload = match kind {
        0 => SpillPayload::Dense(read_dense_payload(&mut r)?),
        1 => {
            let master = read_key(&mut r)?;
            let tokens = r.u32s()?;
            let positions = r.i32s()?;
            let diff = AlignedDiff::read_le(&mut r)?;
            SpillPayload::Mirror(MirrorEntry {
                master,
                tokens,
                positions,
                diff,
            })
        }
        2 => {
            let format = match r.u8()? {
                0 => QuantFormat::Int8,
                1 => QuantFormat::Q4,
                other => bail!("unknown quant format tag {other}"),
            };
            let layers = r.u64()? as usize;
            let len = r.u64()? as usize;
            let d = r.u64()? as usize;
            let block_tokens = r.u64()? as usize;
            let tokens = r.u32s()?;
            let positions = r.i32s()?;
            let k_scales = r.f32s()?;
            let v_scales = r.f32s()?;
            let k_q = r.bytes()?;
            let v_q = r.bytes()?;
            let elems = layers * len * d;
            let expect = match format {
                QuantFormat::Int8 => elems,
                QuantFormat::Q4 => elems.div_ceil(2),
            };
            if k_q.len() != expect || v_q.len() != expect {
                bail!("quantized spill plane size mismatch");
            }
            SpillPayload::Quantized(QuantizedDense {
                format,
                layers,
                len,
                d,
                block_tokens,
                tokens,
                positions,
                k_scales,
                v_scales,
                k_q,
                v_q,
            })
        }
        other => bail!("unknown spill kind {other}"),
    };
    Ok((key, payload))
}

// ---------------------------------------------------------------------
// the cold tier itself
// ---------------------------------------------------------------------

/// What class of payload a cold entry holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColdKind {
    Dense,
    Mirror,
    Quantized,
}

/// Ledger record of one cold entry (the payload itself lives on disk).
#[derive(Clone, Copy, Debug)]
pub(super) struct ColdMeta {
    /// Serialized file length — the cold tier's ledger unit.
    pub bytes: usize,
    pub kind: ColdKind,
    /// Master key a cold mirror depends on (must stay hot-dense or cold
    /// non-mirror, or the mirror is dead).
    pub master: Option<StoreKey>,
    /// Scheduler hint: the round expected to read this key next.
    pub next_use: Option<u64>,
    /// Spill sequence number — file name + deterministic eviction ties.
    pub seq: u64,
}

/// The cold tier: an on-disk spill area with an exact in-memory ledger.
/// All policy (what to spill, when to restore) lives in `CacheStore`;
/// this type owns serialization, files, the cold byte ledger, and cold
/// eviction.
pub struct ColdTier {
    cfg: TierConfig,
    entries: HashMap<StoreKey, ColdMeta>,
    /// Cold mirrors per master key (the master itself may be hot or
    /// cold).
    by_master: HashMap<StoreKey, BTreeSet<StoreKey>>,
    bytes: usize,
    next_seq: u64,
}

impl ColdTier {
    pub(super) fn new(cfg: TierConfig) -> Result<Self> {
        fs::create_dir_all(&cfg.spill_dir).with_context(|| {
            format!("creating spill dir {}", cfg.spill_dir.display())
        })?;
        Ok(ColdTier {
            cfg,
            entries: HashMap::new(),
            by_master: HashMap::new(),
            bytes: 0,
            next_seq: 0,
        })
    }

    fn path(&self, seq: u64) -> PathBuf {
        self.cfg.spill_dir.join(format!("spill-{seq}.tdm"))
    }

    pub(super) fn quantize_dense(&self) -> bool {
        self.cfg.quantize
    }

    pub(super) fn format(&self) -> QuantFormat {
        self.cfg.format
    }

    pub fn capacity_bytes(&self) -> usize {
        self.cfg.cold_bytes
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: &StoreKey) -> bool {
        self.entries.contains_key(key)
    }

    pub(super) fn meta(&self, key: &StoreKey) -> Option<&ColdMeta> {
        self.entries.get(key)
    }

    // tdlint: allow(hash_iter) -- callers are stats sums and assertions
    pub(super) fn iter_meta(
        &self,
    ) -> impl Iterator<Item = (&StoreKey, &ColdMeta)> {
        self.entries.iter()
    }

    /// Cold mirrors referencing `master`, sorted (BTreeSet order).
    pub(super) fn mirrors_of(&self, master: &StoreKey) -> Vec<StoreKey> {
        self.by_master
            .get(master)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    pub(super) fn hint_next_use(&mut self, key: &StoreKey, round: u64) {
        if let Some(m) = self.entries.get_mut(key) {
            m.next_use = Some(round);
        }
    }

    fn detach_edge(&mut self, key: &StoreKey, master: Option<StoreKey>) {
        if let Some(mk) = master {
            if let Some(set) = self.by_master.get_mut(&mk) {
                set.remove(key);
                if set.is_empty() {
                    self.by_master.remove(&mk);
                }
            }
        }
    }

    /// Remove one cold entry (meta + file). Returns whether it existed.
    pub(super) fn remove(&mut self, key: &StoreKey) -> bool {
        let Some(meta) = self.entries.remove(key) else {
            return false;
        };
        self.bytes -= meta.bytes;
        self.detach_edge(key, meta.master);
        let _ = fs::remove_file(self.path(meta.seq));
        true
    }

    /// Dead-drop every cold mirror of `master` (its restore chain broke).
    pub(super) fn drop_mirrors_of(
        &mut self,
        master: &StoreKey,
        counters: &mut StoreCounters,
    ) {
        for mk in self.mirrors_of(master) {
            if self.remove(&mk) {
                counters.cold_dead_drops += 1;
            }
        }
    }

    /// Steps-to-next-use at `clock` (unhinted or stale hints rank as "no
    /// known upcoming use" — first to go).
    fn steps(meta: &ColdMeta, clock: u64) -> u64 {
        match meta.next_use {
            Some(n) if n >= clock => n - clock,
            _ => u64::MAX,
        }
    }

    /// Evict cold entries until `need` more serialized bytes fit: victim
    /// = max steps-to-next-use, tie broken toward the oldest spill seq (a
    /// total order, deterministic regardless of map iteration). Evicting
    /// a cold master dead-drops its cold mirrors. `protect` (the master a
    /// mirror being inserted depends on) is never chosen.
    fn evict_cold(
        &mut self,
        need: usize,
        protect: Option<StoreKey>,
        clock: u64,
        counters: &mut StoreCounters,
    ) {
        while self.bytes + need > self.cfg.cold_bytes
            && !self.entries.is_empty()
        {
            let mut best: Option<(u64, u64, StoreKey)> = None;
            // tdlint: allow(hash_iter) -- seq tie-break gives a total order
            for (k, m) in &self.entries {
                if Some(*k) == protect {
                    continue;
                }
                let s = Self::steps(m, clock);
                let better = match best {
                    None => true,
                    Some((bs, bseq, _)) => {
                        s > bs || (s == bs && m.seq < bseq)
                    }
                };
                if better {
                    best = Some((s, m.seq, *k));
                }
            }
            let Some((_, _, victim)) = best else { break };
            // a cold master's mirrors die with it: their diffs lost the
            // base they apply to
            if self
                .entries
                .get(&victim)
                .is_some_and(|m| m.kind != ColdKind::Mirror)
            {
                self.drop_mirrors_of(&victim, counters);
            }
            self.remove(&victim);
            counters.cold_evictions += 1;
        }
    }

    /// Spill one payload, replacing any stale entry at `key`. Fails when
    /// the serialized payload cannot fit cold capacity even after
    /// eviction, or the file write fails — the caller counts the loss
    /// (`evicted_to_nothing`).
    pub(super) fn insert(
        &mut self,
        key: StoreKey,
        payload: &SpillPayload,
        next_use: Option<u64>,
        clock: u64,
        counters: &mut StoreCounters,
    ) -> Result<()> {
        let buf = encode_payload(&key, payload);
        if buf.len() > self.cfg.cold_bytes {
            bail!(
                "spill payload of {} B exceeds cold capacity {} B",
                buf.len(),
                self.cfg.cold_bytes
            );
        }
        if self.contains(&key) {
            self.remove(&key);
        }
        self.evict_cold(buf.len(), payload.master(), clock, counters);
        if self.bytes + buf.len() > self.cfg.cold_bytes {
            bail!(
                "spill payload of {} B cannot fit beside its protected \
                 master within cold capacity {} B",
                buf.len(),
                self.cfg.cold_bytes
            );
        }
        let seq = self.next_seq;
        let path = self.path(seq);
        fs::write(&path, &buf).with_context(|| {
            format!("writing spill file {}", path.display())
        })?;
        self.next_seq += 1;
        let meta = ColdMeta {
            bytes: buf.len(),
            kind: payload.kind(),
            master: payload.master(),
            next_use,
            seq,
        };
        if let Some(mk) = meta.master {
            self.by_master.entry(mk).or_default().insert(key);
        }
        self.bytes += meta.bytes;
        self.entries.insert(key, meta);
        Ok(())
    }

    /// Take one payload out (meta and file are removed either way).
    /// `None` when absent; `Some(Err)` when the file could not be read or
    /// decoded.
    pub(super) fn take(
        &mut self,
        key: &StoreKey,
    ) -> Option<Result<SpillPayload>> {
        let meta = *self.entries.get(key)?;
        self.entries.remove(key);
        self.bytes -= meta.bytes;
        self.detach_edge(key, meta.master);
        let path = self.path(meta.seq);
        let res = (|| -> Result<SpillPayload> {
            let buf = fs::read(&path).with_context(|| {
                format!("reading spill file {}", path.display())
            })?;
            let (k, p) = decode_payload(&buf)?;
            if k != *key {
                bail!(
                    "spill file {} holds {k:?}, expected {key:?}",
                    path.display()
                );
            }
            Ok(p)
        })();
        let _ = fs::remove_file(&path);
        Some(res)
    }

    /// Panic unless the cold ledger is exact: bytes equal the sum of meta
    /// sizes and stay within capacity, every entry's spill file exists,
    /// and the master reverse index matches the metas both ways.
    // tdlint: allow(hash_iter) -- read-only assertions, no output or state
    pub(super) fn assert_invariants(&self) {
        let mut sum = 0usize;
        for (k, m) in &self.entries {
            sum += m.bytes;
            assert!(
                self.path(m.seq).exists(),
                "missing spill file for cold entry {k:?}"
            );
            match m.master {
                Some(mk) => {
                    assert_eq!(m.kind, ColdKind::Mirror);
                    assert!(
                        self.by_master
                            .get(&mk)
                            .is_some_and(|s| s.contains(k)),
                        "cold mirror {k:?} missing from reverse index"
                    );
                }
                None => assert_ne!(m.kind, ColdKind::Mirror),
            }
        }
        assert_eq!(self.bytes, sum, "cold byte ledger out of balance");
        assert!(
            self.bytes <= self.cfg.cold_bytes,
            "cold tier over capacity: {} > {}",
            self.bytes,
            self.cfg.cold_bytes
        );
        for (mk, set) in &self.by_master {
            assert!(!set.is_empty(), "empty cold reverse-index {mk:?}");
            for s in set {
                assert!(
                    self.entries
                        .get(s)
                        .is_some_and(|m| m.master == Some(*mk)),
                    "stale cold reverse-index edge {mk:?} -> {s:?}"
                );
            }
        }
    }
}

impl Drop for ColdTier {
    fn drop(&mut self) {
        // tdlint: allow(hash_iter) -- file removal, any order works
        for m in self.entries.values() {
            let _ = fs::remove_file(self.path(m.seq));
        }
        // only removed when empty — never recursive on a user path
        let _ = fs::remove_dir(&self.cfg.spill_dir);
    }
}

#[cfg(test)]
mod tests {
    use super::super::diff::diff_blocks;
    use super::super::identity_aligned;
    use super::*;
    use crate::model::ModelSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            vocab: 512,
            max_seq: 64,
            block_tokens: 16,
            check_layer: 1,
            rope_theta: 10000.0,
        }
    }

    fn dense(spec: &ModelSpec, len: usize, fill: f32) -> DenseEntry {
        let mut kv = KvBuf::zeroed(spec.n_layers, len, spec.d_model);
        for (i, x) in kv.k.iter_mut().enumerate() {
            *x = fill + (i % 13) as f32 * 0.37;
        }
        for (i, x) in kv.v.iter_mut().enumerate() {
            *x = -fill - (i % 7) as f32 * 0.11;
        }
        DenseEntry {
            tokens: (0..len as u32).map(|i| 4 + i + fill as u32).collect(),
            positions: (0..len as i32).collect(),
            kv,
        }
    }

    fn key(c: u64) -> StoreKey {
        StoreKey { content: c, role: Role::Segment }
    }

    fn akey(c: u64, agent: usize) -> StoreKey {
        StoreKey { content: c, role: Role::AgentCache { agent } }
    }

    fn tier(name: &str, cold: usize) -> ColdTier {
        let dir = std::env::temp_dir().join(format!(
            "td-tier-unit-{}-{name}",
            std::process::id()
        ));
        ColdTier::new(TierConfig {
            cold_bytes: cold,
            spill_dir: dir,
            quantize: false,
            format: QuantFormat::Int8,
        })
        .unwrap()
    }

    #[test]
    fn dense_payload_codec_round_trips_bitwise() {
        let sp = spec();
        let e = dense(&sp, 33, 2.5);
        let buf =
            encode_payload(&akey(7, 3), &SpillPayload::Dense(e.clone()));
        let (k, p) = decode_payload(&buf).unwrap();
        assert_eq!(k, akey(7, 3));
        match p {
            SpillPayload::Dense(d) => {
                assert_eq!(d.tokens, e.tokens);
                assert_eq!(d.positions, e.positions);
                assert_eq!(d.kv, e.kv, "f32 planes must round trip bitwise");
            }
            _ => panic!("wrong payload kind"),
        }
    }

    #[test]
    fn mirror_payload_codec_round_trips_bitwise() {
        let sp = spec();
        let master = dense(&sp, 64, 1.0);
        let mut mk = master.kv.clone();
        let o = mk.off(0, 17);
        mk.k[o] += 2.0;
        let d = diff_blocks(&master.kv, &mk, 64, sp.block_tokens);
        let m = MirrorEntry {
            master: akey(1, 0),
            tokens: master.tokens.clone(),
            positions: (0..64).collect(),
            diff: identity_aligned(d, 4, 64),
        };
        let buf =
            encode_payload(&akey(2, 1), &SpillPayload::Mirror(m.clone()));
        let (k, p) = decode_payload(&buf).unwrap();
        assert_eq!(k, akey(2, 1));
        match p {
            SpillPayload::Mirror(got) => {
                assert_eq!(got.master, m.master);
                assert_eq!(got.tokens, m.tokens);
                assert_eq!(got.positions, m.positions);
                assert_eq!(got.diff, m.diff);
            }
            _ => panic!("wrong payload kind"),
        }
    }

    #[test]
    fn truncated_payload_is_rejected_not_panicking() {
        let sp = spec();
        let e = dense(&sp, 16, 1.0);
        let buf = encode_payload(&key(1), &SpillPayload::Dense(e));
        assert!(decode_payload(&buf[..buf.len() / 2]).is_err());
        assert!(decode_payload(&buf[..3]).is_err());
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(decode_payload(&bad).is_err());
    }

    #[test]
    fn quantize_dequantize_error_bounded_by_half_scale() {
        let sp = spec();
        let e = dense(&sp, 40, 3.0);
        for format in [QuantFormat::Int8, QuantFormat::Q4] {
            let q = QuantizedDense::quantize(&e, sp.block_tokens, format);
            let back = q.dequantize();
            assert_eq!(back.tokens, e.tokens);
            let nb = 40usize.div_ceil(sp.block_tokens);
            for (plane, scales, orig) in [
                (&back.kv.k, &q.k_scales, &e.kv.k),
                (&back.kv.v, &q.v_scales, &e.kv.v),
            ] {
                for (i, (got, want)) in
                    plane.iter().zip(orig.iter()).enumerate()
                {
                    let s = i / sp.d_model % 40;
                    let l = i / (sp.d_model * 40);
                    let scale = scales[l * nb + s / sp.block_tokens];
                    assert!(
                        (got - want).abs() <= 0.5 * scale + 1e-6,
                        "{format:?} elem {i}: |{got} - {want}| > {}",
                        0.5 * scale
                    );
                }
            }
            // codec round trip of the quantized form is bitwise
            let buf = encode_payload(
                &key(9),
                &SpillPayload::Quantized(q.clone()),
            );
            let (_, p) = decode_payload(&buf).unwrap();
            match p {
                SpillPayload::Quantized(got) => assert_eq!(got, q),
                _ => panic!("wrong payload kind"),
            }
        }
    }

    #[test]
    fn quantized_zero_block_uses_unit_scale() {
        let sp = spec();
        let mut e = dense(&sp, 32, 1.0);
        // zero out block 1 of layer 0's K plane rows
        for s in 16..32 {
            let o = e.kv.off(0, s);
            e.kv.k[o..o + sp.d_model].fill(0.0);
        }
        let q = QuantizedDense::quantize(&e, sp.block_tokens, QuantFormat::Int8);
        assert_eq!(q.k_scales[1], 1.0);
        let back = q.dequantize();
        for s in 16..32 {
            let o = back.kv.off(0, s);
            assert!(back.kv.k[o..o + sp.d_model].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn q4_is_at_least_3x_smaller_than_dense_on_the_wire() {
        let sp = spec();
        let e = dense(&sp, 64, 2.0);
        let dense_len = encode_payload(
            &key(1),
            &SpillPayload::Dense(e.clone()),
        )
        .len();
        let q4_len = encode_payload(
            &key(1),
            &SpillPayload::Quantized(QuantizedDense::quantize(
                &e,
                sp.block_tokens,
                QuantFormat::Q4,
            )),
        )
        .len();
        assert!(
            q4_len * 3 < dense_len,
            "q4 {q4_len} B vs dense {dense_len} B"
        );
    }

    #[test]
    fn cold_tier_insert_take_and_ledger() {
        let sp = spec();
        let mut t = tier("insert-take", 1 << 20);
        let mut c = StoreCounters::default();
        let e = dense(&sp, 32, 1.0);
        t.insert(key(1), &SpillPayload::Dense(e.clone()), Some(2), 1, &mut c)
            .unwrap();
        assert!(t.contains(&key(1)));
        assert!(t.bytes() > 0);
        t.assert_invariants();
        let p = t.take(&key(1)).unwrap().unwrap();
        match p {
            SpillPayload::Dense(d) => assert_eq!(d.kv, e.kv),
            _ => panic!("wrong payload"),
        }
        assert_eq!(t.bytes(), 0);
        assert!(t.take(&key(1)).is_none());
        t.assert_invariants();
    }

    #[test]
    fn cold_eviction_prefers_unhinted_then_oldest_seq() {
        let sp = spec();
        let one = encode_payload(
            &key(0),
            &SpillPayload::Dense(dense(&sp, 16, 0.0)),
        )
        .len();
        let mut t = tier("evict-order", one * 3 + 8);
        let mut c = StoreCounters::default();
        let d = |f: f32| SpillPayload::Dense(dense(&sp, 16, f));
        // key 1 hinted for the next round, keys 2 and 3 unhinted
        t.insert(key(1), &d(1.0), Some(5), 4, &mut c).unwrap();
        t.insert(key(2), &d(2.0), None, 4, &mut c).unwrap();
        t.insert(key(3), &d(3.0), None, 4, &mut c).unwrap();
        // a fourth insert must evict: both 2 and 3 are "never used again"
        // (steps = MAX); the tie breaks to the older spill seq — key 2
        t.insert(key(4), &d(4.0), None, 4, &mut c).unwrap();
        assert!(t.contains(&key(1)), "hinted entry survives");
        assert!(!t.contains(&key(2)), "oldest unhinted entry evicted");
        assert!(t.contains(&key(3)) && t.contains(&key(4)));
        assert_eq!(c.cold_evictions, 1);
        // stale hints rank like unhinted: clock has moved past key 1
        t.insert(key(5), &d(5.0), Some(7), 6, &mut c).unwrap();
        assert!(!t.contains(&key(1)), "stale hint is LRU fodder");
        t.assert_invariants();
    }

    #[test]
    fn cold_evicting_a_master_dead_drops_its_cold_mirrors() {
        let sp = spec();
        let master = dense(&sp, 64, 1.0);
        let mut mk = master.kv.clone();
        let o = mk.off(0, 17);
        mk.k[o] += 2.0;
        let diff = diff_blocks(&master.kv, &mk, 64, sp.block_tokens);
        let m = MirrorEntry {
            master: akey(1, 0),
            tokens: master.tokens.clone(),
            positions: (0..64).collect(),
            diff: identity_aligned(diff, 4, 64),
        };
        let master_len = encode_payload(
            &akey(1, 0),
            &SpillPayload::Dense(master.clone()),
        )
        .len();
        let mirror_len =
            encode_payload(&akey(2, 1), &SpillPayload::Mirror(m.clone()))
                .len();
        let mut t = tier("dead-drop", master_len + mirror_len + 8);
        let mut c = StoreCounters::default();
        t.insert(akey(1, 0), &SpillPayload::Dense(master), None, 0, &mut c)
            .unwrap();
        t.insert(akey(2, 1), &SpillPayload::Mirror(m), None, 0, &mut c)
            .unwrap();
        t.assert_invariants();
        // the next insert evicts the master (oldest seq) -> mirror dies too
        t.insert(
            key(9),
            &SpillPayload::Dense(dense(&sp, 64, 9.0)),
            None,
            0,
            &mut c,
        )
        .unwrap();
        assert!(!t.contains(&akey(1, 0)));
        assert!(!t.contains(&akey(2, 1)), "orphan cold mirror dead-dropped");
        assert_eq!(c.cold_dead_drops, 1);
        assert!(c.cold_evictions >= 1);
        t.assert_invariants();
    }

    #[test]
    fn oversize_cold_insert_rejected() {
        let sp = spec();
        let mut t = tier("oversize", 64);
        let mut c = StoreCounters::default();
        let err = t.insert(
            key(1),
            &SpillPayload::Dense(dense(&sp, 64, 1.0)),
            None,
            0,
            &mut c,
        );
        assert!(err.is_err());
        assert_eq!(t.bytes(), 0);
        t.assert_invariants();
    }

    #[test]
    fn drop_removes_spill_files() {
        let sp = spec();
        let dir = std::env::temp_dir().join(format!(
            "td-tier-unit-{}-dropclean",
            std::process::id()
        ));
        {
            let mut t = ColdTier::new(TierConfig {
                cold_bytes: 1 << 20,
                spill_dir: dir.clone(),
                quantize: false,
                format: QuantFormat::Int8,
            })
            .unwrap();
            let mut c = StoreCounters::default();
            t.insert(
                key(1),
                &SpillPayload::Dense(dense(&sp, 16, 1.0)),
                None,
                0,
                &mut c,
            )
            .unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "drop removes files and the empty dir");
    }

    #[test]
    fn quant_format_parses() {
        assert_eq!("int8".parse::<QuantFormat>().unwrap(), QuantFormat::Int8);
        assert_eq!("Q4".parse::<QuantFormat>().unwrap(), QuantFormat::Q4);
        assert!("fp8".parse::<QuantFormat>().is_err());
    }
}
