//! Cold storage tier behind [`CacheStore`](super::CacheStore): disk
//! spill, payload quantization, and steps-to-next-use metadata (the
//! MT-APC-style hierarchy; ROADMAP "tiered storage" item).
//!
//! Under hot-capacity pressure the store no longer drops entries — it
//! *spills* them here. Mirrors keep their block-sparse
//! [`AlignedDiff`](super::AlignedDiff) form (already 11–17x smaller than
//! dense), and dense payloads are optionally quantized — int8 or Q4 with
//! one f32 scale per (layer, token-block) per plane — before
//! serialization. Every cold entry is one little-endian flat file
//! (`spill-<seq>.tdm`, magic `TDM2`: a CRC32 over the body guards
//! every read; legacy `TDM1` files remain readable for migration)
//! under the configured spill directory; f32 values travel as raw bit
//! patterns, so an unquantized spill → restore round trip is
//! **bitwise**, and `EngineBuilder::quantize(false)` is the
//! equivalence baseline (same discipline as `gather_plan` /
//! `collective_encode`).
//!
//! Fault tolerance (the degradation ladder): spill writes go through
//! `spill-<seq>.tdm.tmp` + `sync_all` + atomic rename, so a crash
//! mid-spill never leaves a torn `.tdm` visible; transient I/O errors
//! retry up to [`MAX_ATTEMPTS`](super::fault::MAX_ATTEMPTS) bounded
//! attempts; persistent write failure surfaces as a typed
//! [`StoreFault`](super::fault::StoreFault) the store converts into
//! `evicted_to_nothing`; a corrupt/unreadable restore **quarantines**
//! the file (renamed `*.quarantine`, never deleted, never served) and
//! the store dead-drops the entry plus its dependent cold mirrors —
//! the engine's miss path recomputes, so token streams never change.
//! With `TierConfig::recover`, construction scans the spill directory
//! and rebuilds the cold index from surviving files (torn `.tmp` and
//! corrupt files quarantined and counted), and `Drop` preserves the
//! directory for the next session. A seeded
//! [`FaultPlan`](super::fault::FaultPlan) injects all of the above
//! deterministically for tests and the `experiments faults` sweep.
//!
//! The tier records, per cold entry, the round scheduler's *next-use
//! hint* (which round will read the key next). Cold eviction — the only
//! lossy step in the hierarchy — removes the entry with the largest
//! steps-to-next-use (unhinted or stale = infinity), the same
//! KVFlow-style priority the hot tier uses under pressure, with ties
//! broken toward the oldest spill sequence number so the choice is
//! deterministic regardless of hash-map iteration order. Evicting a cold
//! master dead-drops its cold mirrors (their diffs have no base left);
//! both losses are counted, never silent.

use std::collections::{BTreeSet, HashMap};
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::diff::{wire, AlignedDiff};
use super::fault::{
    FaultInjector, FaultPlan, ReadFault, StoreFault, WriteFault,
    MAX_ATTEMPTS,
};
use super::{DenseEntry, MirrorEntry, Role, StoreCounters, StoreKey};
use crate::runtime::KvBuf;

/// Quantization format for spilled dense payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantFormat {
    /// 8-bit symmetric: scale = maxabs/127 per (layer, block) per plane.
    Int8,
    /// 4-bit symmetric, two values per byte: scale = maxabs/7.
    Q4,
}

impl QuantFormat {
    fn qmax(self) -> f32 {
        match self {
            QuantFormat::Int8 => 127.0,
            QuantFormat::Q4 => 7.0,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            QuantFormat::Int8 => "int8",
            QuantFormat::Q4 => "q4",
        }
    }
}

impl std::str::FromStr for QuantFormat {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "int8" => QuantFormat::Int8,
            "q4" => QuantFormat::Q4,
            other => bail!("unknown quant format {other:?} (int8 | q4)"),
        })
    }
}

/// Cold-tier configuration (`CacheStore::configure_tier`, fed from
/// `EngineBuilder::cold_tier` / `spill_dir` / `quantize` / `quant_format`).
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// Serialized-byte capacity of the cold tier.
    pub cold_bytes: usize,
    /// Directory the spill files live in (created on configure). With
    /// `recover` off, drop removes this run's spill files, plus the
    /// directory itself when the tier created it and it is empty —
    /// never recursively, since the path is user-supplied. With
    /// `recover` on, drop preserves everything for the next session.
    pub spill_dir: PathBuf,
    /// Quantize dense payloads on spill. `false` keeps spills exact and
    /// is the bitwise-equivalence baseline.
    pub quantize: bool,
    pub format: QuantFormat,
    /// Deterministic fault-injection schedule. `None` (default) adds
    /// zero branches to the I/O path.
    pub fault_plan: Option<FaultPlan>,
    /// Crash-recovery semantics: scan the spill directory at
    /// construction, rebuild the cold index from surviving files
    /// (quarantining torn/corrupt ones), and keep spill files on drop.
    pub recover: bool,
}

// ---------------------------------------------------------------------
// quantization
// ---------------------------------------------------------------------

/// A dense entry quantized per (layer, token-block): one f32 scale per
/// block per plane, values one byte each (int8) or two per byte (Q4).
/// The packed value stream is in `KvBuf` element order, so quantize and
/// dequantize walk the planes identically.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedDense {
    pub format: QuantFormat,
    pub layers: usize,
    pub len: usize,
    pub d: usize,
    pub block_tokens: usize,
    pub tokens: Vec<u32>,
    pub positions: Vec<i32>,
    /// Per (layer, block) K-plane scales, layer-major.
    pub k_scales: Vec<f32>,
    pub v_scales: Vec<f32>,
    pub k_q: Vec<u8>,
    pub v_q: Vec<u8>,
}

// tdlint: allow(panic_path) -- plane is layers*len*d by construction
fn quantize_plane(
    xs: &[f32],
    layers: usize,
    len: usize,
    d: usize,
    block_tokens: usize,
    format: QuantFormat,
) -> (Vec<f32>, Vec<u8>) {
    let nb = len.div_ceil(block_tokens).max(1);
    let qmax = format.qmax();
    let mut scales = Vec::with_capacity(layers * nb);
    let mut qi: Vec<i8> = Vec::with_capacity(xs.len());
    for l in 0..layers {
        for b in 0..nb {
            let lo = (l * len + b * block_tokens) * d;
            let hi = (l * len + len.min((b + 1) * block_tokens)) * d;
            let maxabs = xs[lo..hi]
                .iter()
                .fold(0.0f32, |m, x| m.max(x.abs()));
            // an all-zero block quantizes through a unit scale (0/1 = 0)
            let scale = if maxabs == 0.0 { 1.0 } else { maxabs / qmax };
            scales.push(scale);
            for &x in &xs[lo..hi] {
                qi.push((x / scale).round().clamp(-qmax, qmax) as i8);
            }
        }
    }
    let packed = match format {
        QuantFormat::Int8 => qi.iter().map(|&v| v as u8).collect(),
        QuantFormat::Q4 => {
            // nibble-pack pairs over the whole plane stream (values are in
            // [-7, 7]; stored biased by +8 so a nibble is never sign-lossy)
            let mut out = Vec::with_capacity(qi.len().div_ceil(2));
            for pair in qi.chunks(2) {
                let lo = (pair[0] + 8) as u8 & 0x0f;
                let hi = if pair.len() == 2 {
                    ((pair[1] + 8) as u8 & 0x0f) << 4
                } else {
                    0
                };
                out.push(lo | hi);
            }
            out
        }
    };
    (scales, packed)
}

// tdlint: allow(panic_path) -- packed/scales sized by the quantizer
fn dequantize_plane(
    packed: &[u8],
    scales: &[f32],
    layers: usize,
    len: usize,
    d: usize,
    block_tokens: usize,
    format: QuantFormat,
) -> Vec<f32> {
    let nb = len.div_ceil(block_tokens).max(1);
    let mut out = Vec::with_capacity(layers * len * d);
    let unpack = |i: usize| -> i8 {
        match format {
            QuantFormat::Int8 => packed[i] as i8,
            QuantFormat::Q4 => {
                let byte = packed[i / 2];
                let nib = if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
                nib as i8 - 8
            }
        }
    };
    let mut i = 0usize;
    for l in 0..layers {
        for s in 0..len {
            let scale = scales[l * nb + s / block_tokens];
            for _ in 0..d {
                out.push(unpack(i) as f32 * scale);
                i += 1;
            }
        }
    }
    out
}

impl QuantizedDense {
    /// Quantize a dense entry per (layer, token-block). Per-element error
    /// of the round trip is bounded by `scale / 2` (scale = block
    /// maxabs / qmax).
    pub fn quantize(
        e: &DenseEntry,
        block_tokens: usize,
        format: QuantFormat,
    ) -> Self {
        let kv = &e.kv;
        let (layers, len, d) = (kv.layers, kv.seq, kv.d);
        let (k_scales, k_q) =
            quantize_plane(&kv.k, layers, len, d, block_tokens, format);
        let (v_scales, v_q) =
            quantize_plane(&kv.v, layers, len, d, block_tokens, format);
        QuantizedDense {
            format,
            layers,
            len,
            d,
            block_tokens,
            tokens: e.tokens.clone(),
            positions: e.positions.clone(),
            k_scales,
            v_scales,
            k_q,
            v_q,
        }
    }

    /// Reconstruct the dense entry (lossy: per-element error <= scale/2).
    pub fn dequantize(&self) -> DenseEntry {
        let mut kv = KvBuf::zeroed(self.layers, self.len, self.d);
        kv.k = dequantize_plane(
            &self.k_q,
            &self.k_scales,
            self.layers,
            self.len,
            self.d,
            self.block_tokens,
            self.format,
        );
        kv.v = dequantize_plane(
            &self.v_q,
            &self.v_scales,
            self.layers,
            self.len,
            self.d,
            self.block_tokens,
            self.format,
        );
        DenseEntry {
            tokens: self.tokens.clone(),
            positions: self.positions.clone(),
            kv,
        }
    }

    /// Bytes of the reconstructed dense form — the hot-tier cost a
    /// restore pays (the store's accounting unit for dense entries).
    pub fn dense_bytes(&self) -> usize {
        2 * self.layers * self.len * self.d * 4 + self.tokens.len() * 8
    }

    /// In-memory bytes of the quantized form itself.
    pub fn bytes(&self) -> usize {
        self.k_q.len()
            + self.v_q.len()
            + (self.k_scales.len() + self.v_scales.len()) * 4
            + self.tokens.len() * 4
            + self.positions.len() * 4
    }
}

// ---------------------------------------------------------------------
// spill payloads + on-disk codec
// ---------------------------------------------------------------------

/// One payload spilled to the cold tier.
#[derive(Clone, Debug)]
pub enum SpillPayload {
    /// Exact dense entry (the `quantize(false)` path — bitwise round
    /// trip).
    Dense(DenseEntry),
    /// Block-sparse mirror (always exact; restoring it needs its master
    /// resident dense, so the restore path re-heats masters first).
    Mirror(MirrorEntry),
    /// Quantized dense entry (lossy; dequantized on restore).
    Quantized(QuantizedDense),
}

impl SpillPayload {
    pub fn kind(&self) -> ColdKind {
        match self {
            SpillPayload::Dense(_) => ColdKind::Dense,
            SpillPayload::Mirror(_) => ColdKind::Mirror,
            SpillPayload::Quantized(_) => ColdKind::Quantized,
        }
    }

    /// Master key a mirror payload depends on (None for dense forms).
    pub fn master(&self) -> Option<StoreKey> {
        match self {
            SpillPayload::Mirror(m) => Some(m.master),
            _ => None,
        }
    }
}

/// Current spill format: `TDM2 | crc32(body) LE | body`, where body is
/// `kind u8 | key | payload`. The CRC is verified on every decode so
/// on-disk corruption is detected, never served as KV.
const MAGIC: &[u8; 4] = b"TDM2";
/// PR 6's checksum-free format: `TDM1 | body`, body identical to TDM2's.
/// Still decoded (no CRC to verify) so pre-existing spill files migrate
/// transparently; never written anymore.
const MAGIC_V1: &[u8; 4] = b"TDM1";

fn put_key(out: &mut Vec<u8>, key: &StoreKey) {
    wire::put_u64(out, key.content);
    match key.role {
        Role::Segment => {
            wire::put_u8(out, 0);
            wire::put_u64(out, 0);
        }
        Role::AgentCache { agent } => {
            wire::put_u8(out, 1);
            wire::put_u64(out, agent as u64);
        }
    }
}

fn read_key(r: &mut wire::Reader) -> Result<StoreKey> {
    let content = r.u64()?;
    let tag = r.u8()?;
    let agent = r.u64()? as usize;
    let role = match tag {
        0 => Role::Segment,
        1 => Role::AgentCache { agent },
        other => bail!("unknown role tag {other} in spill payload"),
    };
    Ok(StoreKey { content, role })
}

fn put_dense_payload(out: &mut Vec<u8>, e: &DenseEntry) {
    wire::put_u32s(out, &e.tokens);
    wire::put_i32s(out, &e.positions);
    wire::put_u64(out, e.kv.layers as u64);
    wire::put_u64(out, e.kv.seq as u64);
    wire::put_u64(out, e.kv.d as u64);
    wire::put_f32s(out, &e.kv.k);
    wire::put_f32s(out, &e.kv.v);
}

fn read_dense_payload(r: &mut wire::Reader) -> Result<DenseEntry> {
    let tokens = r.u32s()?;
    let positions = r.i32s()?;
    let layers = r.u64()? as usize;
    let seq = r.u64()? as usize;
    let d = r.u64()? as usize;
    let k = r.f32s()?;
    let v = r.f32s()?;
    if k.len() != layers * seq * d || v.len() != k.len() {
        bail!("dense spill plane size mismatch");
    }
    let mut kv = KvBuf::zeroed(layers, seq, d);
    kv.k = k;
    kv.v = v;
    Ok(DenseEntry { tokens, positions, kv })
}

/// Serialize `(key, payload)` into one flat spill-file image:
/// `TDM2 | crc32(body) | body`.
pub fn encode_payload(key: &StoreKey, p: &SpillPayload) -> Vec<u8> {
    let mut body = Vec::new();
    wire::put_u8(
        &mut body,
        match p {
            SpillPayload::Dense(_) => 0,
            SpillPayload::Mirror(_) => 1,
            SpillPayload::Quantized(_) => 2,
        },
    );
    put_key(&mut body, key);
    match p {
        SpillPayload::Dense(e) => put_dense_payload(&mut body, e),
        SpillPayload::Mirror(m) => {
            put_key(&mut body, &m.master);
            wire::put_u32s(&mut body, &m.tokens);
            wire::put_i32s(&mut body, &m.positions);
            m.diff.write_le(&mut body);
        }
        SpillPayload::Quantized(q) => {
            wire::put_u8(
                &mut body,
                match q.format {
                    QuantFormat::Int8 => 0,
                    QuantFormat::Q4 => 1,
                },
            );
            wire::put_u64(&mut body, q.layers as u64);
            wire::put_u64(&mut body, q.len as u64);
            wire::put_u64(&mut body, q.d as u64);
            wire::put_u64(&mut body, q.block_tokens as u64);
            wire::put_u32s(&mut body, &q.tokens);
            wire::put_i32s(&mut body, &q.positions);
            wire::put_f32s(&mut body, &q.k_scales);
            wire::put_f32s(&mut body, &q.v_scales);
            wire::put_bytes(&mut body, &q.k_q);
            wire::put_bytes(&mut body, &q.v_q);
        }
    }
    let mut out = Vec::with_capacity(body.len() + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&wire::crc32(&body).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Decode one spill-file image back to `(key, payload)`. `TDM2` images
/// are CRC-verified; legacy `TDM1` images (no checksum) decode as-is.
pub fn decode_payload(buf: &[u8]) -> Result<(StoreKey, SpillPayload)> {
    let magic = buf
        .get(..4)
        .ok_or_else(|| anyhow::anyhow!("spill image shorter than magic"))?;
    let body = if magic == MAGIC.as_slice() {
        let crc_raw: [u8; 4] = buf
            .get(4..8)
            .and_then(|s| s.try_into().ok())
            .ok_or_else(|| anyhow::anyhow!("spill image missing checksum"))?;
        let stored = u32::from_le_bytes(crc_raw);
        let body = buf.get(8..).unwrap_or(&[]);
        let computed = wire::crc32(body);
        if computed != stored {
            bail!(
                "spill checksum mismatch: stored {stored:#010x}, \
                 computed {computed:#010x}"
            );
        }
        body
    } else if magic == MAGIC_V1.as_slice() {
        buf.get(4..).unwrap_or(&[])
    } else {
        bail!("bad spill magic (expected TDM2 or legacy TDM1)");
    };
    let mut r = wire::Reader::new(body);
    let kind = r.u8()?;
    let key = read_key(&mut r)?;
    let payload = match kind {
        0 => SpillPayload::Dense(read_dense_payload(&mut r)?),
        1 => {
            let master = read_key(&mut r)?;
            let tokens = r.u32s()?;
            let positions = r.i32s()?;
            let diff = AlignedDiff::read_le(&mut r)?;
            SpillPayload::Mirror(MirrorEntry {
                master,
                tokens,
                positions,
                diff,
            })
        }
        2 => {
            let format = match r.u8()? {
                0 => QuantFormat::Int8,
                1 => QuantFormat::Q4,
                other => bail!("unknown quant format tag {other}"),
            };
            let layers = r.u64()? as usize;
            let len = r.u64()? as usize;
            let d = r.u64()? as usize;
            let block_tokens = r.u64()? as usize;
            let tokens = r.u32s()?;
            let positions = r.i32s()?;
            let k_scales = r.f32s()?;
            let v_scales = r.f32s()?;
            let k_q = r.bytes()?;
            let v_q = r.bytes()?;
            let elems = layers * len * d;
            let expect = match format {
                QuantFormat::Int8 => elems,
                QuantFormat::Q4 => elems.div_ceil(2),
            };
            if k_q.len() != expect || v_q.len() != expect {
                bail!("quantized spill plane size mismatch");
            }
            SpillPayload::Quantized(QuantizedDense {
                format,
                layers,
                len,
                d,
                block_tokens,
                tokens,
                positions,
                k_scales,
                v_scales,
                k_q,
                v_q,
            })
        }
        other => bail!("unknown spill kind {other}"),
    };
    Ok((key, payload))
}

// ---------------------------------------------------------------------
// the cold tier itself
// ---------------------------------------------------------------------

/// What class of payload a cold entry holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColdKind {
    Dense,
    Mirror,
    Quantized,
}

/// Ledger record of one cold entry (the payload itself lives on disk).
#[derive(Clone, Copy, Debug)]
pub(super) struct ColdMeta {
    /// Serialized file length — the cold tier's ledger unit.
    pub bytes: usize,
    pub kind: ColdKind,
    /// Master key a cold mirror depends on (must stay hot-dense or cold
    /// non-mirror, or the mirror is dead).
    pub master: Option<StoreKey>,
    /// Scheduler hint: the round expected to read this key next.
    pub next_use: Option<u64>,
    /// Spill sequence number — file name + deterministic eviction ties.
    pub seq: u64,
}

/// The cold tier: an on-disk spill area with an exact in-memory ledger.
/// All policy (what to spill, when to restore) lives in `CacheStore`;
/// this type owns serialization, files, the cold byte ledger, and cold
/// eviction.
pub struct ColdTier {
    cfg: TierConfig,
    entries: HashMap<StoreKey, ColdMeta>,
    /// Cold mirrors per master key (the master itself may be hot or
    /// cold).
    by_master: HashMap<StoreKey, BTreeSet<StoreKey>>,
    bytes: usize,
    next_seq: u64,
    /// Live fault injector (None = zero-overhead un-faulted path).
    faults: Option<FaultInjector>,
    /// Whether this tier created the spill directory (drop only removes
    /// a directory it created).
    created_dir: bool,
}

/// Rename `path` to `path.quarantine` (fall back to deletion if the
/// rename itself fails) and count it. Quarantined files are never
/// decoded, never served, and never touched by recovery or drop — they
/// are the forensics trail.
fn quarantine_file(path: &Path, counters: &mut StoreCounters) {
    let mut q = path.as_os_str().to_os_string();
    q.push(".quarantine");
    if fs::rename(path, &q).is_err() {
        let _ = fs::remove_file(path);
    }
    counters.quarantined += 1;
}

/// Crash-safe spill write: `path.tmp` + `sync_all` + atomic rename. A
/// crash at any point leaves either no visible `.tdm` or a complete
/// one — never a torn file recovery could misread.
fn write_atomic(path: &Path, buf: &[u8]) -> std::io::Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let res = (|| {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(buf)?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, path)
    })();
    if res.is_err() {
        let _ = fs::remove_file(&tmp);
    }
    res
}

/// Parse `spill-<seq>.tdm` back to its sequence number (recovery scan).
fn parse_spill_seq(name: &str) -> Option<u64> {
    name.strip_prefix("spill-")?.strip_suffix(".tdm")?.parse().ok()
}

impl ColdTier {
    /// Build the tier. With `cfg.recover`, scans the spill directory
    /// and rebuilds the cold index from surviving files — intact
    /// entries are re-indexed (`recovered_entries`), torn `.tmp` and
    /// corrupt/unreadable files are quarantined (`quarantined`), and
    /// recovered mirrors whose base did not survive are dead-dropped.
    pub(super) fn new(
        cfg: TierConfig,
        counters: &mut StoreCounters,
    ) -> Result<Self> {
        let created_dir = !cfg.spill_dir.exists();
        fs::create_dir_all(&cfg.spill_dir).with_context(|| {
            format!("creating spill dir {}", cfg.spill_dir.display())
        })?;
        let faults = cfg.fault_plan.map(FaultInjector::new);
        let recover = cfg.recover;
        let mut t = ColdTier {
            cfg,
            entries: HashMap::new(),
            by_master: HashMap::new(),
            bytes: 0,
            next_seq: 0,
            faults,
            created_dir,
        };
        if recover {
            t.recover(counters)?;
        }
        Ok(t)
    }

    /// Rebuild the cold index from whatever the spill directory holds.
    /// Files are visited in sequence order (sorted, not read_dir order)
    /// so recovery is deterministic; non-spill files are left alone.
    fn recover(&mut self, counters: &mut StoreCounters) -> Result<()> {
        let mut found: Vec<(u64, PathBuf)> = Vec::new();
        let rd = fs::read_dir(&self.cfg.spill_dir).with_context(|| {
            format!("scanning spill dir {}", self.cfg.spill_dir.display())
        })?;
        for ent in rd.flatten() {
            let path = ent.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str())
            else {
                continue;
            };
            if name.ends_with(".tdm.tmp") {
                // torn mid-spill write: the rename never happened
                quarantine_file(&path, counters);
            } else if let Some(seq) = parse_spill_seq(name) {
                found.push((seq, path));
            }
        }
        found.sort();
        for (seq, path) in found {
            let decoded = fs::read(&path)
                .map_err(anyhow::Error::from)
                .and_then(|buf| decode_payload(&buf).map(|kp| (buf, kp)));
            let (buf, (key, payload)) = match decoded {
                Ok(v) => v,
                Err(_) => {
                    quarantine_file(&path, counters);
                    continue;
                }
            };
            // a crash between write and stale-removal can leave two
            // files for one key: the higher seq is the live one
            if self.entries.get(&key).is_some() {
                self.remove(&key);
                counters.recovered_entries -= 1;
            }
            let meta = ColdMeta {
                bytes: buf.len(),
                kind: payload.kind(),
                master: payload.master(),
                next_use: None,
                seq,
            };
            if let Some(mk) = meta.master {
                self.by_master.entry(mk).or_default().insert(key);
            }
            self.bytes += meta.bytes;
            self.entries.insert(key, meta);
            self.next_seq = self.next_seq.max(seq + 1);
            counters.recovered_entries += 1;
        }
        // recovered mirrors need their base among the recovered
        // non-mirror entries (the hot tier is empty at startup)
        // tdlint: allow(hash_iter) -- keys collected and sorted below
        let mut orphans: Vec<StoreKey> = self
            .entries
            .iter()
            .filter(|(_, m)| {
                m.kind == ColdKind::Mirror
                    && !m.master.is_some_and(|mk| {
                        self.entries
                            .get(&mk)
                            .is_some_and(|b| b.kind != ColdKind::Mirror)
                    })
            })
            .map(|(k, _)| *k)
            .collect();
        orphans.sort();
        for k in orphans {
            self.remove(&k);
            counters.cold_dead_drops += 1;
            counters.dead_dropped_dependents += 1;
        }
        // shrink back under capacity (all recovered entries are
        // unhinted, so eviction goes oldest-seq first)
        self.evict_cold(0, None, 0, counters);
        Ok(())
    }

    fn path(&self, seq: u64) -> PathBuf {
        self.cfg.spill_dir.join(format!("spill-{seq}.tdm"))
    }

    pub(super) fn quantize_dense(&self) -> bool {
        self.cfg.quantize
    }

    pub(super) fn format(&self) -> QuantFormat {
        self.cfg.format
    }

    pub fn capacity_bytes(&self) -> usize {
        self.cfg.cold_bytes
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: &StoreKey) -> bool {
        self.entries.contains_key(key)
    }

    pub(super) fn meta(&self, key: &StoreKey) -> Option<&ColdMeta> {
        self.entries.get(key)
    }

    // tdlint: allow(hash_iter) -- callers are stats sums and assertions
    pub(super) fn iter_meta(
        &self,
    ) -> impl Iterator<Item = (&StoreKey, &ColdMeta)> {
        self.entries.iter()
    }

    /// Cold mirrors referencing `master`, sorted (BTreeSet order).
    pub(super) fn mirrors_of(&self, master: &StoreKey) -> Vec<StoreKey> {
        self.by_master
            .get(master)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    pub(super) fn hint_next_use(&mut self, key: &StoreKey, round: u64) {
        if let Some(m) = self.entries.get_mut(key) {
            m.next_use = Some(round);
        }
    }

    fn detach_edge(&mut self, key: &StoreKey, master: Option<StoreKey>) {
        if let Some(mk) = master {
            if let Some(set) = self.by_master.get_mut(&mk) {
                set.remove(key);
                if set.is_empty() {
                    self.by_master.remove(&mk);
                }
            }
        }
    }

    /// Remove one cold entry (meta + file). Returns whether it existed.
    pub(super) fn remove(&mut self, key: &StoreKey) -> bool {
        let Some(meta) = self.entries.remove(key) else {
            return false;
        };
        self.bytes -= meta.bytes;
        self.detach_edge(key, meta.master);
        let _ = fs::remove_file(self.path(meta.seq));
        true
    }

    /// Dead-drop every cold mirror of `master` (its restore chain broke).
    pub(super) fn drop_mirrors_of(
        &mut self,
        master: &StoreKey,
        counters: &mut StoreCounters,
    ) {
        for mk in self.mirrors_of(master) {
            if self.remove(&mk) {
                counters.cold_dead_drops += 1;
            }
        }
    }

    /// Like [`Self::drop_mirrors_of`], but for bases lost to a *fault*
    /// (quarantined or unwritable) rather than a capacity decision —
    /// also counted as `dead_dropped_dependents` so fault blast radius
    /// is observable separately from eviction policy.
    pub(super) fn drop_dependents_of(
        &mut self,
        master: &StoreKey,
        counters: &mut StoreCounters,
    ) {
        for mk in self.mirrors_of(master) {
            if self.remove(&mk) {
                counters.cold_dead_drops += 1;
                counters.dead_dropped_dependents += 1;
            }
        }
    }

    /// Steps-to-next-use at `clock` (unhinted or stale hints rank as "no
    /// known upcoming use" — first to go).
    fn steps(meta: &ColdMeta, clock: u64) -> u64 {
        match meta.next_use {
            Some(n) if n >= clock => n - clock,
            _ => u64::MAX,
        }
    }

    /// Evict cold entries until `need` more serialized bytes fit: victim
    /// = max steps-to-next-use, tie broken toward the oldest spill seq (a
    /// total order, deterministic regardless of map iteration). Evicting
    /// a cold master dead-drops its cold mirrors. `protect` (the master a
    /// mirror being inserted depends on) is never chosen.
    fn evict_cold(
        &mut self,
        need: usize,
        protect: Option<StoreKey>,
        clock: u64,
        counters: &mut StoreCounters,
    ) {
        while self.bytes + need > self.cfg.cold_bytes
            && !self.entries.is_empty()
        {
            let mut best: Option<(u64, u64, StoreKey)> = None;
            // tdlint: allow(hash_iter) -- seq tie-break gives a total order
            for (k, m) in &self.entries {
                if Some(*k) == protect {
                    continue;
                }
                let s = Self::steps(m, clock);
                let better = match best {
                    None => true,
                    Some((bs, bseq, _)) => {
                        s > bs || (s == bs && m.seq < bseq)
                    }
                };
                if better {
                    best = Some((s, m.seq, *k));
                }
            }
            let Some((_, _, victim)) = best else { break };
            // a cold master's mirrors die with it: their diffs lost the
            // base they apply to
            if self
                .entries
                .get(&victim)
                .is_some_and(|m| m.kind != ColdKind::Mirror)
            {
                self.drop_mirrors_of(&victim, counters);
            }
            self.remove(&victim);
            counters.cold_evictions += 1;
        }
    }

    /// Spill one payload, replacing any stale entry at `key`. Fails
    /// typed: [`StoreFault::Capacity`] when the serialized payload
    /// cannot fit cold capacity even after eviction,
    /// [`StoreFault::Io`] when the crash-safe write (tmp + sync +
    /// rename) still fails after [`MAX_ATTEMPTS`] bounded attempts —
    /// the caller counts the loss (`evicted_to_nothing`).
    pub(super) fn insert(
        &mut self,
        key: StoreKey,
        payload: &SpillPayload,
        next_use: Option<u64>,
        clock: u64,
        counters: &mut StoreCounters,
    ) -> std::result::Result<(), StoreFault> {
        let buf = encode_payload(&key, payload);
        if buf.len() > self.cfg.cold_bytes {
            return Err(StoreFault::Capacity {
                need: buf.len(),
                cap: self.cfg.cold_bytes,
            });
        }
        if self.contains(&key) {
            self.remove(&key);
        }
        self.evict_cold(buf.len(), payload.master(), clock, counters);
        if self.bytes + buf.len() > self.cfg.cold_bytes {
            // the protected master of the incoming mirror occupies the
            // remainder — a capacity fault, not an I/O one
            return Err(StoreFault::Capacity {
                need: buf.len(),
                cap: self.cfg.cold_bytes,
            });
        }
        let seq = self.next_seq;
        let path = self.path(seq);
        // one fault decision per logical write, drawn before any
        // attempt — retries never consume randomness
        let fault = match self.faults.as_mut() {
            Some(inj) => inj.write_fault(),
            None => WriteFault::None,
        };
        let mut attempt = 0;
        loop {
            let injected = match fault {
                WriteFault::None => false,
                WriteFault::Transient => attempt == 0,
                WriteFault::Persistent => true,
            };
            let res = if injected {
                Err(StoreFault::Io {
                    op: "write",
                    detail: format!(
                        "injected spill-write failure for {}",
                        path.display()
                    ),
                })
            } else {
                write_atomic(&path, &buf).map_err(|e| StoreFault::Io {
                    op: "write",
                    detail: format!(
                        "writing spill file {}: {e}",
                        path.display()
                    ),
                })
            };
            match res {
                Ok(()) => break,
                Err(f) => {
                    counters.io_errors += 1;
                    attempt += 1;
                    if attempt < MAX_ATTEMPTS {
                        counters.retries += 1;
                    } else {
                        return Err(f);
                    }
                }
            }
        }
        self.next_seq += 1;
        let meta = ColdMeta {
            bytes: buf.len(),
            kind: payload.kind(),
            master: payload.master(),
            next_use,
            seq,
        };
        if let Some(mk) = meta.master {
            self.by_master.entry(mk).or_default().insert(key);
        }
        self.bytes += meta.bytes;
        self.entries.insert(key, meta);
        Ok(())
    }

    /// Take one payload out. `None` when absent; `Some(Err)` carries
    /// the typed fault after the degradation ladder ran its course:
    /// transient read errors were retried (bounded), and a
    /// corrupt/truncated/unreadable file was **quarantined** (renamed
    /// `*.quarantine`) — the entry's ledger record is gone either way,
    /// so the caller's recompute path takes over.
    pub(super) fn take(
        &mut self,
        key: &StoreKey,
        counters: &mut StoreCounters,
    ) -> Option<std::result::Result<SpillPayload, StoreFault>> {
        let meta = *self.entries.get(key)?;
        self.entries.remove(key);
        self.bytes -= meta.bytes;
        self.detach_edge(key, meta.master);
        let path = self.path(meta.seq);
        // one fault decision per logical read (see insert)
        let fault = match self.faults.as_mut() {
            Some(inj) => inj.read_fault(),
            None => ReadFault::None,
        };
        let mut attempt = 0;
        let read = loop {
            let injected = match fault {
                ReadFault::Transient => attempt == 0,
                ReadFault::Persistent => true,
                _ => false,
            };
            let res = if injected {
                Err(StoreFault::Io {
                    op: "read",
                    detail: format!(
                        "injected spill-read failure for {}",
                        path.display()
                    ),
                })
            } else {
                fs::read(&path).map_err(|e| StoreFault::Io {
                    op: "read",
                    detail: format!(
                        "reading spill file {}: {e}",
                        path.display()
                    ),
                })
            };
            match res {
                Ok(buf) => break Ok(buf),
                Err(f) => {
                    counters.io_errors += 1;
                    attempt += 1;
                    if attempt < MAX_ATTEMPTS {
                        counters.retries += 1;
                    } else {
                        break Err(f);
                    }
                }
            }
        };
        let res = match read {
            Err(f) => {
                // unreadable after bounded retries: keep the file for
                // forensics, but never as a live spill
                quarantine_file(&path, counters);
                Err(f)
            }
            Ok(mut buf) => {
                // injected data faults model what the disk returned
                if let Some(inj) = self.faults.as_mut() {
                    match fault {
                        ReadFault::Corrupt => inj.corrupt_bytes(&mut buf),
                        ReadFault::Truncate => {
                            let at = inj.truncate_at(buf.len());
                            buf.truncate(at);
                        }
                        _ => {}
                    }
                }
                match decode_payload(&buf) {
                    Ok((k, p)) if k == *key => {
                        let _ = fs::remove_file(&path);
                        Ok(p)
                    }
                    Ok((k, _)) => {
                        quarantine_file(&path, counters);
                        Err(StoreFault::Corrupt {
                            detail: format!(
                                "spill file {} holds {k:?}, expected \
                                 {key:?}",
                                path.display()
                            ),
                        })
                    }
                    Err(e) => {
                        quarantine_file(&path, counters);
                        Err(StoreFault::Corrupt { detail: e.to_string() })
                    }
                }
            }
        };
        Some(res)
    }

    /// Panic unless the cold ledger is exact: bytes equal the sum of meta
    /// sizes and stay within capacity, every entry's spill file exists,
    /// and the master reverse index matches the metas both ways.
    // tdlint: allow(hash_iter) -- read-only assertions, no output or state
    pub(super) fn assert_invariants(&self) {
        let mut sum = 0usize;
        for (k, m) in &self.entries {
            sum += m.bytes;
            assert!(
                self.path(m.seq).exists(),
                "missing spill file for cold entry {k:?}"
            );
            match m.master {
                Some(mk) => {
                    assert_eq!(m.kind, ColdKind::Mirror);
                    assert!(
                        self.by_master
                            .get(&mk)
                            .is_some_and(|s| s.contains(k)),
                        "cold mirror {k:?} missing from reverse index"
                    );
                }
                None => assert_ne!(m.kind, ColdKind::Mirror),
            }
        }
        assert_eq!(self.bytes, sum, "cold byte ledger out of balance");
        assert!(
            self.bytes <= self.cfg.cold_bytes,
            "cold tier over capacity: {} > {}",
            self.bytes,
            self.cfg.cold_bytes
        );
        for (mk, set) in &self.by_master {
            assert!(!set.is_empty(), "empty cold reverse-index {mk:?}");
            for s in set {
                assert!(
                    self.entries
                        .get(s)
                        .is_some_and(|m| m.master == Some(*mk)),
                    "stale cold reverse-index edge {mk:?} -> {s:?}"
                );
            }
        }
    }
}

impl Drop for ColdTier {
    fn drop(&mut self) {
        if self.cfg.recover {
            // recovery semantics: spill files survive the session so
            // the next tier can rebuild from them
            return;
        }
        // every live entry's file was created this run (without
        // `recover`, files only enter the ledger via `insert`), so
        // removing them touches nothing pre-existing
        // tdlint: allow(hash_iter) -- file removal, any order works
        for m in self.entries.values() {
            let _ = fs::remove_file(self.path(m.seq));
        }
        // only the directory this tier created, and only when empty —
        // never recursive on a user path
        if self.created_dir {
            let _ = fs::remove_dir(&self.cfg.spill_dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::diff::diff_blocks;
    use super::super::identity_aligned;
    use super::*;
    use crate::model::ModelSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            n_layers: 2,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            vocab: 512,
            max_seq: 64,
            block_tokens: 16,
            check_layer: 1,
            rope_theta: 10000.0,
        }
    }

    fn dense(spec: &ModelSpec, len: usize, fill: f32) -> DenseEntry {
        let mut kv = KvBuf::zeroed(spec.n_layers, len, spec.d_model);
        for (i, x) in kv.k.iter_mut().enumerate() {
            *x = fill + (i % 13) as f32 * 0.37;
        }
        for (i, x) in kv.v.iter_mut().enumerate() {
            *x = -fill - (i % 7) as f32 * 0.11;
        }
        DenseEntry {
            tokens: (0..len as u32).map(|i| 4 + i + fill as u32).collect(),
            positions: (0..len as i32).collect(),
            kv,
        }
    }

    fn key(c: u64) -> StoreKey {
        StoreKey { content: c, role: Role::Segment }
    }

    fn akey(c: u64, agent: usize) -> StoreKey {
        StoreKey { content: c, role: Role::AgentCache { agent } }
    }

    fn unit_dir(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "td-tier-unit-{}-{name}",
            std::process::id()
        ))
    }

    fn cfg(dir: PathBuf, cold: usize) -> TierConfig {
        TierConfig {
            cold_bytes: cold,
            spill_dir: dir,
            quantize: false,
            format: QuantFormat::Int8,
            fault_plan: None,
            recover: false,
        }
    }

    fn tier(name: &str, cold: usize) -> ColdTier {
        let mut c = StoreCounters::default();
        ColdTier::new(cfg(unit_dir(name), cold), &mut c).unwrap()
    }

    #[test]
    fn dense_payload_codec_round_trips_bitwise() {
        let sp = spec();
        let e = dense(&sp, 33, 2.5);
        let buf =
            encode_payload(&akey(7, 3), &SpillPayload::Dense(e.clone()));
        let (k, p) = decode_payload(&buf).unwrap();
        assert_eq!(k, akey(7, 3));
        match p {
            SpillPayload::Dense(d) => {
                assert_eq!(d.tokens, e.tokens);
                assert_eq!(d.positions, e.positions);
                assert_eq!(d.kv, e.kv, "f32 planes must round trip bitwise");
            }
            _ => panic!("wrong payload kind"),
        }
    }

    #[test]
    fn mirror_payload_codec_round_trips_bitwise() {
        let sp = spec();
        let master = dense(&sp, 64, 1.0);
        let mut mk = master.kv.clone();
        let o = mk.off(0, 17);
        mk.k[o] += 2.0;
        let d = diff_blocks(&master.kv, &mk, 64, sp.block_tokens);
        let m = MirrorEntry {
            master: akey(1, 0),
            tokens: master.tokens.clone(),
            positions: (0..64).collect(),
            diff: identity_aligned(d, 4, 64),
        };
        let buf =
            encode_payload(&akey(2, 1), &SpillPayload::Mirror(m.clone()));
        let (k, p) = decode_payload(&buf).unwrap();
        assert_eq!(k, akey(2, 1));
        match p {
            SpillPayload::Mirror(got) => {
                assert_eq!(got.master, m.master);
                assert_eq!(got.tokens, m.tokens);
                assert_eq!(got.positions, m.positions);
                assert_eq!(got.diff, m.diff);
            }
            _ => panic!("wrong payload kind"),
        }
    }

    #[test]
    fn truncated_payload_is_rejected_not_panicking() {
        let sp = spec();
        let e = dense(&sp, 16, 1.0);
        let buf = encode_payload(&key(1), &SpillPayload::Dense(e));
        assert!(decode_payload(&buf[..buf.len() / 2]).is_err());
        assert!(decode_payload(&buf[..3]).is_err());
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(decode_payload(&bad).is_err());
    }

    #[test]
    fn quantize_dequantize_error_bounded_by_half_scale() {
        let sp = spec();
        let e = dense(&sp, 40, 3.0);
        for format in [QuantFormat::Int8, QuantFormat::Q4] {
            let q = QuantizedDense::quantize(&e, sp.block_tokens, format);
            let back = q.dequantize();
            assert_eq!(back.tokens, e.tokens);
            let nb = 40usize.div_ceil(sp.block_tokens);
            for (plane, scales, orig) in [
                (&back.kv.k, &q.k_scales, &e.kv.k),
                (&back.kv.v, &q.v_scales, &e.kv.v),
            ] {
                for (i, (got, want)) in
                    plane.iter().zip(orig.iter()).enumerate()
                {
                    let s = i / sp.d_model % 40;
                    let l = i / (sp.d_model * 40);
                    let scale = scales[l * nb + s / sp.block_tokens];
                    assert!(
                        (got - want).abs() <= 0.5 * scale + 1e-6,
                        "{format:?} elem {i}: |{got} - {want}| > {}",
                        0.5 * scale
                    );
                }
            }
            // codec round trip of the quantized form is bitwise
            let buf = encode_payload(
                &key(9),
                &SpillPayload::Quantized(q.clone()),
            );
            let (_, p) = decode_payload(&buf).unwrap();
            match p {
                SpillPayload::Quantized(got) => assert_eq!(got, q),
                _ => panic!("wrong payload kind"),
            }
        }
    }

    #[test]
    fn quantized_zero_block_uses_unit_scale() {
        let sp = spec();
        let mut e = dense(&sp, 32, 1.0);
        // zero out block 1 of layer 0's K plane rows
        for s in 16..32 {
            let o = e.kv.off(0, s);
            e.kv.k[o..o + sp.d_model].fill(0.0);
        }
        let q = QuantizedDense::quantize(&e, sp.block_tokens, QuantFormat::Int8);
        assert_eq!(q.k_scales[1], 1.0);
        let back = q.dequantize();
        for s in 16..32 {
            let o = back.kv.off(0, s);
            assert!(back.kv.k[o..o + sp.d_model].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn q4_is_at_least_3x_smaller_than_dense_on_the_wire() {
        let sp = spec();
        let e = dense(&sp, 64, 2.0);
        let dense_len = encode_payload(
            &key(1),
            &SpillPayload::Dense(e.clone()),
        )
        .len();
        let q4_len = encode_payload(
            &key(1),
            &SpillPayload::Quantized(QuantizedDense::quantize(
                &e,
                sp.block_tokens,
                QuantFormat::Q4,
            )),
        )
        .len();
        assert!(
            q4_len * 3 < dense_len,
            "q4 {q4_len} B vs dense {dense_len} B"
        );
    }

    #[test]
    fn cold_tier_insert_take_and_ledger() {
        let sp = spec();
        let mut t = tier("insert-take", 1 << 20);
        let mut c = StoreCounters::default();
        let e = dense(&sp, 32, 1.0);
        t.insert(key(1), &SpillPayload::Dense(e.clone()), Some(2), 1, &mut c)
            .unwrap();
        assert!(t.contains(&key(1)));
        assert!(t.bytes() > 0);
        t.assert_invariants();
        let p = t.take(&key(1), &mut c).unwrap().unwrap();
        match p {
            SpillPayload::Dense(d) => assert_eq!(d.kv, e.kv),
            _ => panic!("wrong payload"),
        }
        assert_eq!(t.bytes(), 0);
        assert!(t.take(&key(1), &mut c).is_none());
        assert_eq!(c.io_errors + c.retries + c.quarantined, 0);
        t.assert_invariants();
    }

    #[test]
    fn cold_eviction_prefers_unhinted_then_oldest_seq() {
        let sp = spec();
        let one = encode_payload(
            &key(0),
            &SpillPayload::Dense(dense(&sp, 16, 0.0)),
        )
        .len();
        let mut t = tier("evict-order", one * 3 + 8);
        let mut c = StoreCounters::default();
        let d = |f: f32| SpillPayload::Dense(dense(&sp, 16, f));
        // key 1 hinted for the next round, keys 2 and 3 unhinted
        t.insert(key(1), &d(1.0), Some(5), 4, &mut c).unwrap();
        t.insert(key(2), &d(2.0), None, 4, &mut c).unwrap();
        t.insert(key(3), &d(3.0), None, 4, &mut c).unwrap();
        // a fourth insert must evict: both 2 and 3 are "never used again"
        // (steps = MAX); the tie breaks to the older spill seq — key 2
        t.insert(key(4), &d(4.0), None, 4, &mut c).unwrap();
        assert!(t.contains(&key(1)), "hinted entry survives");
        assert!(!t.contains(&key(2)), "oldest unhinted entry evicted");
        assert!(t.contains(&key(3)) && t.contains(&key(4)));
        assert_eq!(c.cold_evictions, 1);
        // stale hints rank like unhinted: clock has moved past key 1
        t.insert(key(5), &d(5.0), Some(7), 6, &mut c).unwrap();
        assert!(!t.contains(&key(1)), "stale hint is LRU fodder");
        t.assert_invariants();
    }

    #[test]
    fn cold_evicting_a_master_dead_drops_its_cold_mirrors() {
        let sp = spec();
        let master = dense(&sp, 64, 1.0);
        let mut mk = master.kv.clone();
        let o = mk.off(0, 17);
        mk.k[o] += 2.0;
        let diff = diff_blocks(&master.kv, &mk, 64, sp.block_tokens);
        let m = MirrorEntry {
            master: akey(1, 0),
            tokens: master.tokens.clone(),
            positions: (0..64).collect(),
            diff: identity_aligned(diff, 4, 64),
        };
        let master_len = encode_payload(
            &akey(1, 0),
            &SpillPayload::Dense(master.clone()),
        )
        .len();
        let mirror_len =
            encode_payload(&akey(2, 1), &SpillPayload::Mirror(m.clone()))
                .len();
        let mut t = tier("dead-drop", master_len + mirror_len + 8);
        let mut c = StoreCounters::default();
        t.insert(akey(1, 0), &SpillPayload::Dense(master), None, 0, &mut c)
            .unwrap();
        t.insert(akey(2, 1), &SpillPayload::Mirror(m), None, 0, &mut c)
            .unwrap();
        t.assert_invariants();
        // the next insert evicts the master (oldest seq) -> mirror dies too
        t.insert(
            key(9),
            &SpillPayload::Dense(dense(&sp, 64, 9.0)),
            None,
            0,
            &mut c,
        )
        .unwrap();
        assert!(!t.contains(&akey(1, 0)));
        assert!(!t.contains(&akey(2, 1)), "orphan cold mirror dead-dropped");
        assert_eq!(c.cold_dead_drops, 1);
        assert!(c.cold_evictions >= 1);
        t.assert_invariants();
    }

    #[test]
    fn oversize_cold_insert_rejected() {
        let sp = spec();
        let mut t = tier("oversize", 64);
        let mut c = StoreCounters::default();
        let err = t.insert(
            key(1),
            &SpillPayload::Dense(dense(&sp, 64, 1.0)),
            None,
            0,
            &mut c,
        );
        assert!(err.is_err());
        assert_eq!(t.bytes(), 0);
        t.assert_invariants();
    }

    #[test]
    fn drop_removes_spill_files() {
        let sp = spec();
        let dir = unit_dir("dropclean");
        {
            let mut c = StoreCounters::default();
            let mut t =
                ColdTier::new(cfg(dir.clone(), 1 << 20), &mut c).unwrap();
            t.insert(
                key(1),
                &SpillPayload::Dense(dense(&sp, 16, 1.0)),
                None,
                0,
                &mut c,
            )
            .unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "drop removes files and the empty dir");
    }

    #[test]
    fn drop_leaves_preexisting_dir_and_foreign_files_alone() {
        let sp = spec();
        let dir = unit_dir("drop-foreign");
        fs::create_dir_all(&dir).unwrap();
        let foreign = dir.join("user-data.txt");
        fs::write(&foreign, b"not a spill file").unwrap();
        {
            let mut c = StoreCounters::default();
            let mut t =
                ColdTier::new(cfg(dir.clone(), 1 << 20), &mut c).unwrap();
            t.insert(
                key(1),
                &SpillPayload::Dense(dense(&sp, 16, 1.0)),
                None,
                0,
                &mut c,
            )
            .unwrap();
        }
        assert!(
            foreign.exists() && dir.exists(),
            "pre-existing dir and foreign files survive drop"
        );
        fs::remove_file(&foreign).unwrap();
        fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn drop_with_recover_preserves_spill_files() {
        let sp = spec();
        let dir = unit_dir("drop-recover");
        {
            let mut c = StoreCounters::default();
            let mut rcfg = cfg(dir.clone(), 1 << 20);
            rcfg.recover = true;
            let mut t = ColdTier::new(rcfg, &mut c).unwrap();
            t.insert(
                key(1),
                &SpillPayload::Dense(dense(&sp, 16, 1.0)),
                None,
                0,
                &mut c,
            )
            .unwrap();
        }
        let survivors: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.path())
            .collect();
        assert_eq!(survivors.len(), 1, "spill file survives the session");
        for p in survivors {
            fs::remove_file(p).unwrap();
        }
        fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn spill_write_is_atomic_no_tmp_left_behind() {
        let sp = spec();
        let dir = unit_dir("atomic");
        let mut c = StoreCounters::default();
        let mut t = ColdTier::new(cfg(dir.clone(), 1 << 20), &mut c).unwrap();
        t.insert(
            key(1),
            &SpillPayload::Dense(dense(&sp, 16, 1.0)),
            None,
            0,
            &mut c,
        )
        .unwrap();
        let names: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["spill-0.tdm".to_string()]);
    }

    #[test]
    fn tdm2_detects_a_flipped_bit_tdm1_legacy_still_decodes() {
        let sp = spec();
        let e = dense(&sp, 24, 1.5);
        let buf = encode_payload(&key(3), &SpillPayload::Dense(e.clone()));
        assert_eq!(&buf[..4], b"TDM2");
        // flip one payload bit: the CRC catches it
        let mut bad = buf.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x10;
        let err = decode_payload(&bad).unwrap_err();
        assert!(
            err.to_string().contains("checksum"),
            "corruption is a checksum error, got: {err}"
        );
        // a legacy TDM1 image is the same body without the CRC word
        let mut v1 = Vec::new();
        v1.extend_from_slice(b"TDM1");
        v1.extend_from_slice(&buf[8..]);
        let (k, p) = decode_payload(&v1).unwrap();
        assert_eq!(k, key(3));
        match p {
            SpillPayload::Dense(d) => assert_eq!(d.kv, e.kv),
            _ => panic!("wrong payload kind"),
        }
    }

    #[test]
    fn corrupt_restore_quarantines_and_reports_typed_fault() {
        let sp = spec();
        let dir = unit_dir("quarantine");
        let mut c = StoreCounters::default();
        let mut fcfg = cfg(dir.clone(), 1 << 20);
        fcfg.fault_plan = Some(FaultPlan {
            corrupt: 1.0,
            ..FaultPlan::quiet(99)
        });
        let mut t = ColdTier::new(fcfg, &mut c).unwrap();
        t.insert(
            key(1),
            &SpillPayload::Dense(dense(&sp, 16, 1.0)),
            None,
            0,
            &mut c,
        )
        .unwrap();
        let got = t.take(&key(1), &mut c).unwrap();
        assert!(
            matches!(got, Err(StoreFault::Corrupt { .. })),
            "100% corruption must surface as StoreFault::Corrupt"
        );
        assert_eq!(c.quarantined, 1);
        assert!(!t.contains(&key(1)));
        assert!(
            dir.join("spill-0.tdm.quarantine").exists(),
            "corrupt file renamed, not deleted"
        );
        t.assert_invariants();
        drop(t);
        fs::remove_file(dir.join("spill-0.tdm.quarantine")).unwrap();
        fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn transient_faults_retry_and_succeed_persistent_write_fails_typed() {
        let sp = spec();
        let mut c = StoreCounters::default();
        let dir = unit_dir("transient");
        let mut fcfg = cfg(dir, 1 << 20);
        fcfg.fault_plan = Some(FaultPlan {
            write_fail: 1.0,
            read_fail: 1.0,
            transient: 1.0,
            ..FaultPlan::quiet(5)
        });
        let mut t = ColdTier::new(fcfg, &mut c).unwrap();
        let e = dense(&sp, 16, 2.0);
        // transient write: one retry, then success
        t.insert(key(1), &SpillPayload::Dense(e.clone()), None, 0, &mut c)
            .unwrap();
        assert_eq!((c.io_errors, c.retries), (1, 1));
        // transient read: one retry, then a bitwise restore
        match t.take(&key(1), &mut c).unwrap().unwrap() {
            SpillPayload::Dense(d) => assert_eq!(d.kv, e.kv),
            _ => panic!("wrong payload"),
        }
        assert_eq!((c.io_errors, c.retries, c.quarantined), (2, 2, 0));

        // persistent write: bounded attempts then a typed Io fault
        let mut c2 = StoreCounters::default();
        let mut pcfg = cfg(unit_dir("persistent"), 1 << 20);
        pcfg.fault_plan = Some(FaultPlan {
            write_fail: 1.0,
            transient: 0.0,
            ..FaultPlan::quiet(5)
        });
        let mut t2 = ColdTier::new(pcfg, &mut c2).unwrap();
        let err = t2
            .insert(key(1), &SpillPayload::Dense(e), None, 0, &mut c2)
            .unwrap_err();
        assert!(matches!(err, StoreFault::Io { op: "write", .. }));
        assert_eq!(c2.io_errors, MAX_ATTEMPTS as u64);
        assert_eq!(c2.retries, MAX_ATTEMPTS as u64 - 1);
        assert!(!t2.contains(&key(1)));
        t2.assert_invariants();
    }

    #[test]
    fn recovery_rebuilds_index_quarantines_torn_and_corrupt_files() {
        let sp = spec();
        let dir = unit_dir("recover-rt");
        let e1 = dense(&sp, 16, 1.0);
        let e2 = dense(&sp, 24, 2.0);
        {
            let mut c = StoreCounters::default();
            let mut rcfg = cfg(dir.clone(), 1 << 20);
            rcfg.recover = true;
            let mut t = ColdTier::new(rcfg, &mut c).unwrap();
            t.insert(key(1), &SpillPayload::Dense(e1.clone()), None, 0, &mut c)
                .unwrap();
            t.insert(key(2), &SpillPayload::Dense(e2.clone()), None, 0, &mut c)
                .unwrap();
            // "crash": drop with recover on keeps every file
        }
        // corrupt one surviving file on disk + plant a torn tmp write
        let f2 = dir.join("spill-1.tdm");
        let mut bytes = fs::read(&f2).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        fs::write(&f2, &bytes).unwrap();
        fs::write(dir.join("spill-7.tdm.tmp"), b"torn mid-write").unwrap();

        let mut c = StoreCounters::default();
        let mut rcfg = cfg(dir.clone(), 1 << 20);
        rcfg.recover = true;
        let mut t = ColdTier::new(rcfg, &mut c).unwrap();
        assert_eq!(c.recovered_entries, 1, "intact entry re-indexed");
        assert_eq!(c.quarantined, 2, "torn tmp + corrupt file quarantined");
        assert!(t.contains(&key(1)));
        assert!(!t.contains(&key(2)));
        assert!(dir.join("spill-1.tdm.quarantine").exists());
        assert!(dir.join("spill-7.tdm.tmp.quarantine").exists());
        t.assert_invariants();
        // the recovered entry restores bitwise
        match t.take(&key(1), &mut c).unwrap().unwrap() {
            SpillPayload::Dense(d) => assert_eq!(d.kv, e1.kv),
            _ => panic!("wrong payload"),
        }
        // fresh spills continue past the recovered sequence numbers
        t.insert(key(9), &SpillPayload::Dense(e2), None, 0, &mut c)
            .unwrap();
        assert!(t.meta(&key(9)).unwrap().seq >= 2);
        drop(t);
        for f in fs::read_dir(&dir).unwrap().flatten() {
            fs::remove_file(f.path()).unwrap();
        }
        fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn recovery_dead_drops_mirrors_with_no_surviving_base() {
        let sp = spec();
        let dir = unit_dir("recover-orphan");
        let master = dense(&sp, 64, 1.0);
        let mut mk = master.kv.clone();
        let o = mk.off(0, 17);
        mk.k[o] += 2.0;
        let d = diff_blocks(&master.kv, &mk, 64, sp.block_tokens);
        let m = MirrorEntry {
            master: akey(1, 0),
            tokens: master.tokens.clone(),
            positions: (0..64).collect(),
            diff: identity_aligned(d, 4, 64),
        };
        {
            let mut c = StoreCounters::default();
            let mut rcfg = cfg(dir.clone(), 1 << 20);
            rcfg.recover = true;
            let mut t = ColdTier::new(rcfg, &mut c).unwrap();
            t.insert(
                akey(1, 0),
                &SpillPayload::Dense(master),
                None,
                0,
                &mut c,
            )
            .unwrap();
            t.insert(akey(2, 1), &SpillPayload::Mirror(m), None, 0, &mut c)
                .unwrap();
        }
        // lose the master's file outright (simulated disk loss)
        fs::remove_file(dir.join("spill-0.tdm")).unwrap();
        let mut c = StoreCounters::default();
        let mut rcfg = cfg(dir.clone(), 1 << 20);
        rcfg.recover = true;
        let t = ColdTier::new(rcfg, &mut c).unwrap();
        assert!(
            !t.contains(&akey(2, 1)),
            "mirror without a surviving base is dead-dropped"
        );
        assert_eq!(c.dead_dropped_dependents, 1);
        assert_eq!(c.cold_dead_drops, 1);
        assert!(t.is_empty());
        t.assert_invariants();
        drop(t);
        for f in fs::read_dir(&dir).unwrap().flatten() {
            fs::remove_file(f.path()).unwrap();
        }
        fs::remove_dir(&dir).unwrap();
    }

    #[test]
    fn fault_schedule_is_replayable() {
        let sp = spec();
        let plan = FaultPlan {
            write_fail: 0.4,
            read_fail: 0.3,
            corrupt: 0.2,
            transient: 0.5,
            ..FaultPlan::quiet(1234)
        };
        let run = |name: &str| -> (Vec<bool>, StoreCounters) {
            let mut c = StoreCounters::default();
            let mut fcfg = cfg(unit_dir(name), 1 << 20);
            fcfg.fault_plan = Some(plan);
            let mut t = ColdTier::new(fcfg, &mut c).unwrap();
            let mut outcomes = Vec::new();
            for i in 0..24u64 {
                let ok = t
                    .insert(
                        key(i),
                        &SpillPayload::Dense(dense(&sp, 16, i as f32)),
                        None,
                        0,
                        &mut c,
                    )
                    .is_ok();
                outcomes.push(ok);
                if ok && i % 2 == 0 {
                    outcomes
                        .push(t.take(&key(i), &mut c).unwrap().is_ok());
                }
            }
            (outcomes, c)
        };
        let (a, ca) = run("replay-a");
        let (b, cb) = run("replay-b");
        assert_eq!(a, b, "same plan, same ops => same fault outcomes");
        assert_eq!(ca, cb, "and identical counters");
        assert!(ca.io_errors > 0, "plan actually injected faults");
    }

    #[test]
    fn quant_format_parses() {
        assert_eq!("int8".parse::<QuantFormat>().unwrap(), QuantFormat::Int8);
        assert_eq!("Q4".parse::<QuantFormat>().unwrap(), QuantFormat::Q4);
        assert!("fp8".parse::<QuantFormat>().is_err());
    }
}
