//! Workload-level integration over the mock runtime: full sessions through
//! the driver at various QPS, policy comparisons at trace level, and
//! failure injection (pool exhaustion, store pressure, oversize rounds).

use std::sync::Arc;

use tokendance::engine::{Engine, Policy};
use tokendance::runtime::{MockRuntime, ModelRuntime};
use tokendance::serve::RoundSubmission;
use tokendance::workload::driver::{drive_independent, drive_sessions};
use tokendance::workload::{
    Family, IndependentWorkload, Session, WorkloadConfig, SCENARIOS,
};

fn eng(policy: Policy, pool: usize) -> Engine {
    Engine::builder("sim-7b")
        .policy(policy)
        .pool_blocks(pool)
        .mock()
        .build()
        .unwrap()
}

#[test]
fn all_scenarios_complete_under_all_policies() {
    for (id, family, _) in SCENARIOS {
        for policy in [Policy::VllmPrefix, Policy::TokenDance] {
            let mut e = eng(policy, 1024);
            let cfg = WorkloadConfig::for_family(family, id, 3, 2);
            let report = drive_sessions(&mut e, &cfg, 1, 1e6, 1).unwrap();
            assert_eq!(report.rounds.len(), 2, "scenario {id} {policy:?}");
            assert_eq!(report.subrequests.len(), 6);
        }
    }
}

#[test]
fn multiple_sessions_interleave() {
    let mut e = eng(Policy::TokenDance, 2048);
    let cfg = WorkloadConfig::generative_agents(1, 3, 3);
    let report = drive_sessions(&mut e, &cfg, 3, 1e6, 5).unwrap();
    assert_eq!(report.rounds.len(), 9);
    assert_eq!(report.subrequests.len(), 27);
    // sessions do not cross-contaminate agents
    assert_eq!(e.pending_count(), 0);
}

#[test]
fn low_qps_round_latency_excludes_idle_time() {
    let mut e = eng(Policy::TokenDance, 1024);
    let cfg = WorkloadConfig::generative_agents(1, 2, 2);
    // very low qps: rounds spaced out; latency counted from offered
    // arrival, so idle gaps must not inflate it
    let report = drive_sessions(&mut e, &cfg, 1, 50.0, 3).unwrap();
    for (_, _, l) in &report.rounds {
        assert!(*l < 5.0, "round latency {l} unreasonable");
    }
}

#[test]
fn independent_workload_frees_pool() {
    let rt = Arc::new(MockRuntime::new());
    let spec = rt.spec("sim-7b").unwrap().clone();
    let mut e = Engine::builder("sim-7b")
        .policy(Policy::VllmPrefix)
        .pool_blocks(4 * spec.n_blocks())
        .runtime(rt)
        .build()
        .unwrap();
    let mut w = IndependentWorkload::new(12, 150, 8, 3);
    let report = drive_independent(&mut e, &mut w, 1e6, 3).unwrap();
    assert_eq!(report.subrequests.len(), 12);
    // one-shot requests release their blocks at completion
    assert_eq!(e.pool().stats().used_blocks, 0);
}

#[test]
fn agents_session_survives_pool_pressure() {
    // pool barely fits two sequences; 5 agents queue through it
    let rt = Arc::new(MockRuntime::new());
    let spec = rt.spec("sim-7b").unwrap().clone();
    let mut e = Engine::builder("sim-7b")
        .policy(Policy::TokenDance)
        .pool_blocks(2 * spec.n_blocks())
        .runtime(rt)
        .build()
        .unwrap();
    let cfg = WorkloadConfig::generative_agents(2, 5, 2);
    let report = drive_sessions(&mut e, &cfg, 1, 1e6, 9).unwrap();
    assert_eq!(report.subrequests.len(), 10);
}

#[test]
fn store_pressure_evicts_but_serves() {
    let mut e = Engine::builder("sim-7b")
        .policy(Policy::TokenDance)
        .pool_blocks(1024)
        .store_bytes(200 << 10) // tiny CPU store
        .mock()
        .build()
        .unwrap();
    let w = WorkloadConfig::generative_agents(1, 4, 3);
    let report = drive_sessions(&mut e, &w, 1, 1e6, 2).unwrap();
    assert_eq!(report.rounds.len(), 3);
    assert!(e.store().bytes() <= 200 << 10, "store respects capacity");
    // pressure must show up somewhere honest: eviction or rejection
    // counters, or a store that simply stayed small
    let c = e.store().counters();
    assert!(
        c.evictions + c.rejected_inserts > 0 || e.store().len() < 20,
        "no lifecycle activity under a tiny store: {c:?}"
    );
    // and never as a dangling mirror or an unbalanced ledger
    e.store().assert_invariants();
}

#[test]
fn oversize_round_rejected_cleanly() {
    let mut e = eng(Policy::TokenDance, 1024);
    // 20 agents x 32-token outputs exceed max_seq once shared
    let cfg = WorkloadConfig::generative_agents(1, 20, 2);
    let mut s = Session::new(cfg, 0);
    // round 0 fits (no shared blocks yet)
    let sub = RoundSubmission::new(s.global_round())
        .requests(s.next_round());
    e.submit_round(sub).unwrap();
    let done = e.drain().unwrap();
    let outs: Vec<(usize, Vec<u32>)> =
        done.iter().map(|c| (c.agent, c.generated.clone())).collect();
    s.absorb(&outs).unwrap();
    // round 1 prompts exceed max_seq -> the whole round must be rejected
    // atomically, leaving the engine clean
    let sub = RoundSubmission::new(s.global_round())
        .requests(s.next_round());
    assert!(
        e.submit_round(sub).is_err(),
        "oversize round must be rejected"
    );
    let _ = e.drain().unwrap();
    assert_eq!(e.pending_count(), 0);
}

#[test]
fn generative_agents_vs_agent_society_profiles() {
    let ga = WorkloadConfig::generative_agents(1, 8, 3);
    let as_ = WorkloadConfig::agent_society(5, 8, 3);
    assert_eq!(ga.family, Family::GenerativeAgents);
    assert_eq!(as_.family, Family::AgentSociety);
    // the paper's contrast: AgentSociety has longer private histories
    assert!(as_.sys_bytes > ga.sys_bytes);
    assert!(as_.keep_turns > ga.keep_turns);
    assert!(ga.max_context() <= 512 && as_.max_context() <= 512);
}
