//! Integration tests over the REAL runtime: AOT artifacts loaded through
//! PJRT, numerics anchored to the python oracle via artifacts/golden.json,
//! and the full engine driven end to end on both simulated model scales.
//!
//! Requires `make artifacts` to have run (skipped otherwise).

use std::path::PathBuf;
use std::sync::Arc;

use tokendance::engine::{AgentRequest, Engine, Policy};
use tokendance::runtime::{
    argmax, DecodeSeq, KvBuf, ModelRuntime, PjrtRuntime, RopeDiffSeq,
};
use tokendance::serve::RoundSubmission;
use tokendance::tokenizer::{encode, BlockKind, RoundAwarePrompt};
use tokendance::util::json::Json;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn runtime() -> Option<Arc<PjrtRuntime>> {
    artifacts_dir().map(|d| Arc::new(PjrtRuntime::load(&d).unwrap()))
}

#[test]
#[ignore = "requires AOT artifacts (run `make artifacts` first)"]
fn golden_prefill_matches_python_oracle() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let golden_text =
        std::fs::read_to_string(dir.join("golden.json")).unwrap();
    let golden = Json::parse(&golden_text).unwrap();
    for model in ["sim-7b", "sim-14b"] {
        let g = golden.get(model).expect("model in golden");
        let tokens: Vec<u32> = g
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap() as u32)
            .collect();
        let len = g.get("len").unwrap().as_usize().unwrap();
        let out = rt.prefill(model, &tokens, len).unwrap();
        // logits prefix
        let want: Vec<f64> = g
            .get("logits_prefix")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        for (i, w) in want.iter().enumerate() {
            assert!(
                (out.logits[i] as f64 - w).abs() < 1e-3,
                "{model} logit[{i}]: {} vs {w}",
                out.logits[i]
            );
        }
        // greedy argmax
        let want_arg = g.get("argmax").unwrap().as_usize().unwrap() as u32;
        assert_eq!(argmax(&out.logits), want_arg, "{model} argmax");
        // K/V checksums over the valid rows
        let spec = rt.spec(model).unwrap().clone();
        let mut ksum = 0f64;
        let mut vsum = 0f64;
        for l in 0..spec.n_layers {
            for s in 0..len {
                ksum += out.kv.k_row(l, s).iter().map(|x| x.abs() as f64).sum::<f64>();
                vsum += out.kv.v_row(l, s).iter().map(|x| x.abs() as f64).sum::<f64>();
            }
        }
        let want_k = g.get("k_sum").unwrap().as_f64().unwrap();
        let want_v = g.get("v_sum").unwrap().as_f64().unwrap();
        assert!((ksum - want_k).abs() / want_k < 1e-4, "{model} k_sum {ksum} vs {want_k}");
        assert!((vsum - want_v).abs() / want_v < 1e-4, "{model} v_sum {vsum} vs {want_v}");
    }
}

#[test]
#[ignore = "requires AOT artifacts (run `make artifacts` first)"]
fn decode_extends_prefill_consistently() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let model = "sim-7b";
    let spec = rt.spec(model).unwrap().clone();
    let toks: Vec<u32> = (0..40u32).map(|i| 4 + (i * 11) % 250).collect();

    // prefill 40 tokens, then decode token 41 and compare against a
    // prefill of 41 tokens
    let p40 = rt.prefill(model, &toks, 40).unwrap();
    let next = 4 + 123u32;
    let mut kv = KvBuf::for_spec(&spec);
    kv.copy_rows_from(&p40.kv, 0, 0, 40);
    let outs = rt
        .decode(model, &[DecodeSeq { token: next, len: 40, kv: &kv }])
        .unwrap();

    let mut toks41 = toks.clone();
    toks41.push(next);
    let p41 = rt.prefill(model, &toks41, 41).unwrap();
    // logits at the new position must match
    for (a, b) in outs[0].logits.iter().zip(&p41.logits) {
        assert!((a - b).abs() < 1e-3, "decode logits diverge: {a} vs {b}");
    }
    // K/V rows for the new token must match
    for l in 0..spec.n_layers {
        let d = spec.d_model;
        let want_k = p41.kv.k_row(l, 40);
        let got_k = &outs[0].k_new[l * d..(l + 1) * d];
        for (a, b) in got_k.iter().zip(want_k) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}

#[test]
#[ignore = "requires AOT artifacts (run `make artifacts` first)"]
fn collective_equals_serial_on_real_model() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let model = "sim-7b";
    let spec = rt.spec(model).unwrap().clone();
    let s = spec.max_seq;
    let toks: Vec<u32> = (0..48u32).map(|i| 4 + (i * 7) % 200).collect();
    let pre = rt.prefill(model, &toks, 48).unwrap();
    let mut cache = KvBuf::for_spec(&spec);
    cache.copy_rows_from(&pre.kv, 0, 0, 48);

    let mut padded = toks.clone();
    padded.resize(s, 0);
    let old: Vec<i32> = (0..s as i32).collect();
    let mut valid = vec![0u8; s];
    valid[..48].iter_mut().for_each(|x| *x = 1);

    let mk = || RopeDiffSeq {
        tokens: &padded,
        old_pos: &old,
        valid: &valid,
        kv: &cache,
    };
    let group = rt.ropediff(model, &[mk(), mk(), mk()]).unwrap();
    let single = rt.ropediff(model, &[mk()]).unwrap();
    for g in &group {
        for (a, b) in g.scores.iter().zip(&single[0].scores) {
            assert!((a - b).abs() < 1e-4, "scores differ: {a} vs {b}");
        }
        let err = g.k_rot.max_abs_diff(&single[0].k_rot);
        assert!(err < 1e-4, "k_rot differs by {err}");
    }
    // prefix reuse at unchanged positions scores ~0
    assert!(
        single[0].scores[..48].iter().all(|&x| x < 1e-2),
        "prefix positions should score ~0: {:?}",
        &single[0].scores[..8]
    );
}

fn mk_prompt(agent: usize, hist: &str, shared: &[Vec<u32>], task: &str)
    -> RoundAwarePrompt
{
    let mut p = RoundAwarePrompt::new();
    p.push(BlockKind::PrivateHistory, encode(hist));
    let n = shared.len().max(1);
    for i in 0..shared.len() {
        p.push(
            BlockKind::SharedOutput { producer: i, round: 0 },
            shared[(i + agent) % n].clone(),
        );
    }
    p.push(BlockKind::RoundTask, encode(task));
    p.pad_blocks(16, encode(" ")[0]);
    p
}

fn run_two_rounds(policy: Policy, rt: Arc<PjrtRuntime>) -> Vec<Vec<Vec<u32>>> {
    let mut eng = Engine::builder("sim-7b")
        .policy(policy)
        .pool_blocks(256)
        .runtime(rt)
        .build()
        .unwrap();
    let mut shared: Vec<Vec<u32>> = Vec::new();
    let mut out = Vec::new();
    for round in 0..2 {
        let mut sub = RoundSubmission::new(round);
        for a in 0..3 {
            let p = mk_prompt(
                a,
                &format!("agent {a} persona"),
                &shared,
                &format!("round {round}"),
            );
            sub.push(AgentRequest {
                agent: a,
                round,
                prompt: p,
                max_new_tokens: 16,
                retain: true,
            });
        }
        eng.submit_round(sub).unwrap();
        let done = eng.drain().unwrap();
        assert_eq!(done.len(), 3);
        let mut outs = vec![Vec::new(); 3];
        shared = vec![Vec::new(); 3];
        for c in &done {
            outs[c.agent] = c.generated.clone();
            shared[c.agent] = c.generated.clone();
        }
        out.push(outs);
    }
    out
}

#[test]
#[ignore = "requires AOT artifacts (run `make artifacts` first)"]
fn engine_end_to_end_all_policies_real_model() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // exact policies agree bit-for-bit
    let v = run_two_rounds(Policy::VllmPrefix, rt.clone());
    let o = run_two_rounds(Policy::CacheBlendOrdinary, rt.clone());
    assert_eq!(v, o, "exact paths must produce identical greedy streams");

    // PIC policies agree with each other (collective == per-request)
    let c = run_two_rounds(Policy::CacheBlendFull, rt.clone());
    let t = run_two_rounds(Policy::TokenDance, rt.clone());
    assert_eq!(c, t, "TokenDance must equal CacheBlend outputs (§6.6)");

    // all policies generate full-length outputs
    for outs in [&v, &c] {
        for r in outs.iter() {
            for g in r {
                assert_eq!(g.len(), 16);
            }
        }
    }
}

#[test]
#[ignore = "requires AOT artifacts (run `make artifacts` first)"]
fn engine_real_model_14b_smoke() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut eng = Engine::builder("sim-14b")
        .policy(Policy::TokenDance)
        .pool_blocks(256)
        .runtime(rt)
        .build()
        .unwrap();
    let mut sub = RoundSubmission::new(0);
    for a in 0..2 {
        let p = mk_prompt(a, "persona", &[], "go");
        sub.push(AgentRequest {
            agent: a,
            round: 0,
            prompt: p,
            max_new_tokens: 8,
            retain: true,
        });
    }
    eng.submit_round(sub).unwrap();
    let done = eng.drain().unwrap();
    assert_eq!(done.len(), 2);
}
