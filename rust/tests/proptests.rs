//! Property-based tests over the coordinator invariants (routing,
//! batching, storage, reuse) using a seeded-sweep helper — the offline
//! stand-in for proptest: each property runs across many generated cases
//! with shrink-free reporting of the failing seed.

use tokendance::collector::{run_reuse, CollectorConfig, ReuseTask};
use tokendance::engine::{AgentRequest, Engine, Policy};
use tokendance::serve::RoundSubmission;
use tokendance::kvcache::KvPool;
use tokendance::model::{Buckets, ModelSpec};
use tokendance::pic::{select_important_blocks, ImportanceConfig, INVALID_SCORE};
use tokendance::rounds::{detect_pattern, pair_overlap, segment_blocks,
                         segment_prompt, DetectorConfig, SegmentedPrompt};
use tokendance::runtime::{BlockProvenance, KvBuf, MockRuntime,
                          ModelRuntime};
use tokendance::store::{diff_blocks, diff_blocks_tol,
                        diff_blocks_tol_masked, gather_permuted_master,
                        identity_aligned, match_blocks_by_content,
                        CacheStore, DenseEntry, Fetched, MirrorEntry,
                        QuantFormat, Role, StoreKey, TierConfig};
use tokendance::tokenizer::{encode, split_segments, BlockKind,
                            RoundAwarePrompt, TTSEP_ID};
use tokendance::util::rng::Rng;

/// Run `prop` for `cases` seeds; panic with the seed on failure. The
/// `PROPTEST_CASES` env var, when set, *caps* every property's case
/// count — CI pins it so tier-1 runs are fast and the executed case set
/// is identical on every run (the seeds themselves are always fixed).
fn forall(cases: u64, prop: impl Fn(&mut Rng)) {
    let cases = match std::env::var("PROPTEST_CASES") {
        Ok(v) => match v.parse::<u64>() {
            Ok(n) => cases.min(n.max(1)),
            Err(_) => cases,
        },
        Err(_) => cases,
    };
    for seed in 0..cases {
        let mut rng = Rng::new(0x9E3779B97F4A7C15 ^ seed);
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut rng)),
        );
        if let Err(e) = r {
            eprintln!(">>> property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn spec() -> ModelSpec {
    MockRuntime::new().spec("sim-7b").unwrap().clone()
}

// ---------------------------------------------------------------------
// tokenizer / rounds
// ---------------------------------------------------------------------

#[test]
fn prop_serialize_split_roundtrip() {
    forall(200, |rng| {
        let n_blocks = rng.range(1, 6);
        let mut p = RoundAwarePrompt::new();
        for i in 0..n_blocks {
            let len = rng.below(20);
            let toks: Vec<u32> = (0..len)
                .map(|_| 4 + rng.below(256) as u32)
                .collect();
            let kind = match i {
                0 => BlockKind::PrivateHistory,
                _ => BlockKind::SharedOutput { producer: i, round: 0 },
            };
            p.push(kind, toks);
        }
        let wire = p.serialize();
        let segs = split_segments(&wire);
        assert_eq!(segs.len(), n_blocks);
        for (seg, blk) in segs.iter().zip(&p.blocks) {
            assert_eq!(*seg, &blk.tokens[..]);
        }
        // no separators leak into plain serialization
        assert!(!p.serialize_plain().contains(&TTSEP_ID));
    });
}

#[test]
fn prop_pad_blocks_alignment() {
    forall(100, |rng| {
        let mut p = RoundAwarePrompt::new();
        for _ in 0..rng.range(1, 5) {
            let len = rng.range(1, 40);
            p.push(
                BlockKind::PrivateHistory,
                (0..len).map(|_| 4 + rng.below(200) as u32).collect(),
            );
        }
        p.pad_blocks(16, 36);
        let mut cursor = 0;
        for b in &p.blocks {
            assert_eq!(cursor % 16, 0, "every block starts aligned");
            assert_eq!(b.tokens.len() % 16, 0);
            cursor += b.tokens.len();
        }
    });
}

#[test]
fn prop_segment_hash_position_independent() {
    forall(100, |rng| {
        let shared: Vec<u32> =
            (0..rng.range(1, 30)).map(|_| 4 + rng.below(200) as u32).collect();
        let mk = |pre_len: usize, rng: &mut Rng| {
            let mut p = RoundAwarePrompt::new();
            p.push(
                BlockKind::PrivateHistory,
                (0..pre_len).map(|_| 4 + rng.below(200) as u32).collect(),
            );
            p.push(
                BlockKind::SharedOutput { producer: 0, round: 0 },
                shared.clone(),
            );
            segment_prompt(&p.serialize())
        };
        let a = mk(rng.range(1, 50), rng);
        let b = mk(rng.range(1, 50), rng);
        assert_eq!(a.segments[1].hash, b.segments[1].hash);
    });
}

#[test]
fn prop_detector_never_groups_disjoint_prompts() {
    forall(60, |rng| {
        let mk = |rng: &mut Rng| {
            let mut p = RoundAwarePrompt::new();
            p.push(
                BlockKind::PrivateHistory,
                (0..rng.range(10, 60))
                    .map(|_| 4 + rng.below(250) as u32)
                    .collect(),
            );
            segment_prompt(&p.serialize())
        };
        let prompts: Vec<_> = (0..rng.range(2, 6)).map(|_| mk(rng)).collect();
        let refs: Vec<&_> = prompts.iter().collect();
        // random prompts virtually never share segments: every cohort
        // must be a singleton
        let cfg = DetectorConfig::default();
        let part = detect_pattern(&refs, &cfg);
        assert!(part.is_independent(&cfg));
        assert_eq!(part.cohorts.len(), prompts.len());
        assert!(part.cohorts.iter().all(|c| c.members.len() == 1));
    });
}

// ---------------------------------------------------------------------
// sharing-cohort clustering
// ---------------------------------------------------------------------

/// A random round: each prompt owns a private block and carries a random
/// subset of a shared-block pool — the generator behind the partition
/// properties (cohort structure is arbitrary: chains, teams, singletons).
fn random_round(rng: &mut Rng) -> Vec<SegmentedPrompt> {
    let n = rng.range(2, 8);
    let n_shared = rng.range(1, 5);
    let shared: Vec<Vec<u32>> = (0..n_shared)
        .map(|_| {
            (0..rng.range(8, 24))
                .map(|_| 4 + rng.below(200) as u32)
                .collect()
        })
        .collect();
    (0..n)
        .map(|i| {
            let mut p = RoundAwarePrompt::new();
            p.push(
                BlockKind::PrivateHistory,
                (0..rng.range(4, 40))
                    .map(|_| 4 + rng.below(250) as u32)
                    .collect(),
            );
            for s in &shared {
                if rng.f64() < 0.5 {
                    p.push(
                        BlockKind::SharedOutput { producer: i, round: 0 },
                        s.clone(),
                    );
                }
            }
            segment_prompt(&p.serialize())
        })
        .collect()
}

#[test]
fn prop_cohort_partition_covers_every_request_exactly_once() {
    forall(80, |rng| {
        let prompts = random_round(rng);
        let refs: Vec<&SegmentedPrompt> = prompts.iter().collect();
        let part = detect_pattern(&refs, &DetectorConfig::default());
        let mut seen = vec![0usize; prompts.len()];
        for c in &part.cohorts {
            assert!(!c.members.is_empty(), "no empty cohorts");
            assert!(
                c.members.windows(2).all(|w| w[0] < w[1]),
                "members ascend"
            );
            for &m in &c.members {
                seen[m] += 1;
            }
        }
        assert!(
            seen.iter().all(|&x| x == 1),
            "partition must cover every request exactly once: {seen:?}"
        );
        // canonical cohort order: by smallest member
        assert!(part
            .cohorts
            .windows(2)
            .all(|w| w[0].members[0] < w[1].members[0]));
    });
}

#[test]
fn prop_co_cohort_members_meet_overlap_threshold() {
    forall(80, |rng| {
        let prompts = random_round(rng);
        let refs: Vec<&SegmentedPrompt> = prompts.iter().collect();
        let cfg = DetectorConfig::default();
        let part = detect_pattern(&refs, &cfg);
        for c in &part.cohorts {
            if c.members.len() < 2 {
                continue;
            }
            // every member was pulled in by at least one threshold edge
            for &m in &c.members {
                assert!(
                    c.members.iter().any(|&o| {
                        o != m
                            && pair_overlap(refs[m], refs[o])
                                >= cfg.min_shared_frac
                    }),
                    "member {m} has no threshold edge inside its cohort"
                );
            }
        }
        // and, conversely, any threshold pair is co-cohort
        let cohort_of = |m: usize| {
            part.cohorts
                .iter()
                .position(|c| c.members.contains(&m))
                .unwrap()
        };
        for a in 0..prompts.len() {
            for b in a + 1..prompts.len() {
                if pair_overlap(refs[a], refs[b]) >= cfg.min_shared_frac {
                    assert_eq!(
                        cohort_of(a),
                        cohort_of(b),
                        "threshold pair ({a},{b}) split across cohorts"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_cohort_partition_is_permutation_invariant() {
    forall(60, |rng| {
        let prompts = random_round(rng);
        let n = prompts.len();
        let refs: Vec<&SegmentedPrompt> = prompts.iter().collect();
        let cfg = DetectorConfig::default();
        let part = detect_pattern(&refs, &cfg);

        let perm = rng.choose(n, n); // a random permutation of 0..n
        let permuted: Vec<&SegmentedPrompt> =
            perm.iter().map(|&i| refs[i]).collect();
        let part_p = detect_pattern(&permuted, &cfg);

        // map the permuted partition back to original indices and
        // compare as sets of (member set, shared hash set)
        let canon = |cohorts: Vec<(Vec<usize>, Vec<u64>)>| {
            let mut v = cohorts;
            for (m, _) in v.iter_mut() {
                m.sort_unstable();
            }
            v.sort();
            v
        };
        let orig = canon(
            part.cohorts
                .iter()
                .map(|c| (c.members.clone(), c.shared_hashes.clone()))
                .collect(),
        );
        let mapped = canon(
            part_p
                .cohorts
                .iter()
                .map(|c| {
                    (
                        c.members.iter().map(|&m| perm[m]).collect(),
                        c.shared_hashes.clone(),
                    )
                })
                .collect(),
        );
        assert_eq!(orig, mapped, "partition changed under permutation");
    });
}

#[test]
fn prop_full_topology_round_is_single_cohort() {
    use tokendance::workload::{Session, Topology, WorkloadConfig};
    forall(20, |rng| {
        let agents = rng.range(2, 7);
        let cfg = WorkloadConfig::generative_agents(1, agents, 2)
            .with_topology(Topology::Full);
        let session_id = rng.below(10);
        let mut s = Session::new(cfg, session_id);
        let _ = s.next_round();
        // synthetic round-0 outputs feed round 1's shared blocks
        let outs: Vec<(usize, Vec<u32>)> = (0..agents)
            .map(|a| {
                (
                    s.agent_id(a),
                    (0..16).map(|_| 4 + rng.below(200) as u32).collect(),
                )
            })
            .collect();
        s.absorb(&outs).unwrap();
        let reqs = s.next_round();
        let segs: Vec<SegmentedPrompt> =
            reqs.iter().map(|r| segment_blocks(&r.prompt)).collect();
        let refs: Vec<&SegmentedPrompt> = segs.iter().collect();
        let dcfg = DetectorConfig::default();
        let part = detect_pattern(&refs, &dcfg);
        assert!(
            part.is_all_gather(&dcfg),
            "Full topology must always yield exactly one cohort \
             ({} agents, {} cohorts)",
            agents,
            part.cohorts.len()
        );
        assert_eq!(
            part.cohorts[0].members,
            (0..agents).collect::<Vec<_>>()
        );
    });
}

// ---------------------------------------------------------------------
// kv pool
// ---------------------------------------------------------------------

#[test]
fn prop_pool_never_leaks_blocks() {
    forall(100, |rng| {
        let sp = spec();
        let total = rng.range(8, 64);
        let mut pool = KvPool::new(&sp, total);
        let mut live = Vec::new();
        for _ in 0..rng.range(5, 40) {
            if rng.f64() < 0.6 || live.is_empty() {
                let want = rng.range(1, 80);
                if let Ok(t) = pool.allocate(want) {
                    live.push(t);
                }
            } else {
                let i = rng.below(live.len());
                let t = live.swap_remove(i);
                pool.release(&t);
            }
            let st = pool.stats();
            assert_eq!(st.used_blocks + st.free_blocks, total);
            let live_blocks: usize =
                live.iter().map(|t| t.blocks.len()).sum();
            assert_eq!(st.used_blocks, live_blocks);
        }
        for t in &live {
            pool.release(t);
        }
        assert_eq!(pool.stats().used_blocks, 0);
    });
}

#[test]
fn prop_scatter_gather_identity() {
    forall(60, |rng| {
        let sp = spec();
        let mut pool = KvPool::for_seqs(&sp, 2);
        let len = rng.range(1, sp.max_seq);
        let mut src = KvBuf::for_spec(&sp);
        for x in src.k.iter_mut() {
            *x = (rng.f64() - 0.5) as f32;
        }
        for x in src.v.iter_mut() {
            *x = (rng.f64() - 0.5) as f32;
        }
        let mut t = pool.allocate(len).unwrap();
        t.len = len;
        pool.scatter(&t, &src, len);
        let got = pool.gather(&t);
        for l in 0..sp.n_layers {
            for s in 0..len {
                assert_eq!(got.k_row(l, s), src.k_row(l, s));
                assert_eq!(got.v_row(l, s), src.v_row(l, s));
            }
        }
    });
}

// ---------------------------------------------------------------------
// scratch arena
// ---------------------------------------------------------------------

#[test]
fn prop_scratch_checkout_is_always_zero() {
    use tokendance::runtime::KvScratch;
    forall(60, |rng| {
        let sp = spec();
        let mut sc = KvScratch::for_spec(&sp);
        let mut live: Vec<(KvBuf, usize)> = Vec::new();
        for _ in 0..rng.range(5, 40) {
            if rng.f64() < 0.5 || live.is_empty() {
                let mut buf = sc.checkout();
                assert!(
                    buf.k.iter().all(|&x| x == 0.0)
                        && buf.v.iter().all(|&x| x == 0.0),
                    "checkout leaked stale rows between checkins"
                );
                // dirty a random prefix of rows on both planes
                let rows = rng.below(sp.max_seq + 1);
                for l in 0..sp.n_layers {
                    for s in 0..rows {
                        let o = buf.off(l, s);
                        buf.k[o] = 1.0 + s as f32;
                        buf.v[o + sp.d_model - 1] = -2.0;
                    }
                }
                live.push((buf, rows));
            } else {
                let i = rng.below(live.len());
                let (buf, rows) = live.swap_remove(i);
                sc.checkin(buf, rows);
            }
        }
        for (buf, rows) in live {
            sc.checkin(buf, rows);
        }
        // drain the pool: every recycled buffer must come back clean
        let pooled = sc.free_len();
        for _ in 0..pooled {
            let buf = sc.checkout();
            assert!(buf.k.iter().all(|&x| x == 0.0), "stale K in pool");
            assert!(buf.v.iter().all(|&x| x == 0.0), "stale V in pool");
        }
    });
}

// ---------------------------------------------------------------------
// diff encoding
// ---------------------------------------------------------------------

#[test]
fn prop_diff_roundtrip_reconstructs_mirror() {
    forall(80, |rng| {
        let sp = spec();
        let len = rng.range(16, sp.max_seq);
        let mut master = KvBuf::zeroed(sp.n_layers, len, sp.d_model);
        for x in master.k.iter_mut() {
            *x = (rng.f64() - 0.5) as f32;
        }
        for x in master.v.iter_mut() {
            *x = (rng.f64() - 0.5) as f32;
        }
        let mut mirror = master.clone();
        // perturb random positions
        for _ in 0..rng.below(20) {
            let l = rng.below(sp.n_layers);
            let s = rng.below(len);
            let o = mirror.off(l, s) + rng.below(sp.d_model);
            mirror.k[o] += 1.0;
        }
        let d = diff_blocks_tol(&master, &mirror, len, sp.block_tokens, 0.0);
        let mut rebuilt = master.clone();
        d.apply_to(&mut rebuilt);
        assert_eq!(rebuilt, mirror);
    });
}

#[test]
fn prop_provenance_skip_diff_equals_full_scan() {
    // the collective-encode invariant: a diff whose scan skips blocks the
    // provenance proves clean is bitwise-identical to the exhaustive
    // full scan, across random dirty patterns and partial tail blocks
    forall(120, |rng| {
        let bt = 16usize;
        let layers = rng.range(1, 4);
        let d = rng.range(4, 12);
        let nb = rng.range(1, 9);
        // partial tails: valid_len lands anywhere inside the last block
        let valid_len = (nb - 1) * bt + rng.range(1, bt + 1);
        let seq = nb * bt + rng.below(33);
        let mut master = KvBuf::zeroed(layers, seq, d);
        for (i, x) in master.k.iter_mut().enumerate() {
            *x = ((i * 31) % 97) as f32 * 0.01;
        }
        for (i, x) in master.v.iter_mut().enumerate() {
            *x = -(((i * 17) % 89) as f32) * 0.01;
        }
        let mut mirror = master.clone();

        // random dirty pattern: perturbed blocks get a real change and an
        // all-dirty provenance; clean blocks get matching Copied records
        // on both sides (same synthetic entry, same rows)
        let key = StoreKey { content: 0xC0FFEE, role: Role::Segment };
        let mut mirror_prov = BlockProvenance::dirty(nb, bt);
        let mut master_prov = BlockProvenance::dirty(nb, bt);
        for b in 0..nb {
            if rng.below(2) == 0 {
                let slot = (b * bt + rng.below(bt)).min(valid_len - 1);
                let l = rng.below(layers);
                let o = mirror.off(l, slot) + rng.below(d);
                if rng.below(2) == 0 {
                    mirror.k[o] += 5.0;
                } else {
                    mirror.v[o] += 5.0;
                }
            } else {
                mirror_prov.record_copy(b * bt, bt, key, b * bt, None);
                master_prov.record_copy(b * bt, bt, key, b * bt, None);
            }
        }
        let src_block: Vec<i32> = (0..nb as i32).collect();
        let mask =
            mirror_prov.skip_mask(&master_prov, &src_block, valid_len);
        // sanity: the mask never covers a perturbed block (perturbed
        // blocks carry dirty provenance by construction)
        let full = diff_blocks_tol(&master, &mirror, valid_len, bt, 0.0);
        for &bid in &full.block_ids {
            assert!(!mask[bid as usize], "mask covers a dirty block");
        }
        let masked = diff_blocks_tol_masked(
            &master, &mirror, valid_len, bt, 0.0, Some(&mask),
        );
        assert_eq!(masked, full, "skip path must equal the full scan");
    });
}

#[test]
fn prop_content_match_is_sound() {
    forall(80, |rng| {
        let bt = 16;
        let n = rng.range(2, 8);
        let master: Vec<u32> = (0..n * bt)
            .map(|_| 4 + rng.below(200) as u32)
            .collect();
        // mirror = permutation of master blocks (+ maybe a novel block)
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut mirror = Vec::new();
        for &b in &order {
            mirror.extend_from_slice(&master[b * bt..(b + 1) * bt]);
        }
        let map = match_blocks_by_content(&master, &mirror, bt);
        for (mb, &src) in map.iter().enumerate() {
            assert!(src >= 0, "permuted block must match");
            // soundness: matched content is identical
            let s = src as usize;
            assert_eq!(
                &master[s * bt..(s + 1) * bt],
                &mirror[mb * bt..(mb + 1) * bt]
            );
        }
    });
}

#[test]
fn prop_gather_permuted_respects_map() {
    forall(60, |rng| {
        let sp = spec();
        let bt = sp.block_tokens;
        let n = rng.range(2, 8);
        let len = n * bt;
        let mut master = KvBuf::zeroed(sp.n_layers, len, sp.d_model);
        for (i, x) in master.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        let positions: Vec<i32> = (0..len as i32).collect();
        let src_map: Vec<i32> = (0..n)
            .map(|_| {
                if rng.f64() < 0.2 {
                    -1
                } else {
                    rng.below(n) as i32
                }
            })
            .collect();
        let (out, src_pos) = gather_permuted_master(
            &master, &positions, &src_map, len, bt, sp.max_seq,
        );
        for (b, &src) in src_map.iter().enumerate() {
            for t in 0..bt {
                let slot = b * bt + t;
                if src < 0 {
                    assert_eq!(out.k_row(0, slot), vec![0.0; sp.d_model]);
                    assert_eq!(src_pos[slot], slot as i32);
                } else {
                    let ms = src as usize * bt + t;
                    assert_eq!(out.k_row(0, slot), master.k_row(0, ms));
                    assert_eq!(src_pos[slot], ms as i32);
                }
            }
        }
    });
}

// ---------------------------------------------------------------------
// diff-aware store lifecycle
// ---------------------------------------------------------------------

#[test]
fn prop_store_churn_preserves_invariants() {
    forall(30, |rng| {
        let sp = spec();
        let bt = sp.block_tokens;
        let mk_key = |i: usize| StoreKey {
            content: i as u64,
            role: if i % 2 == 0 {
                Role::Segment
            } else {
                Role::AgentCache { agent: i }
            },
        };
        let mk_dense = |len: usize, salt: u32| {
            let mut kv = KvBuf::zeroed(sp.n_layers, len, sp.d_model);
            for (i, x) in kv.k.iter_mut().enumerate() {
                *x = ((i as u32) ^ salt) as f32 / 100.0;
            }
            DenseEntry {
                tokens: (0..len as u32)
                    .map(|i| 4 + ((i ^ salt) % 200))
                    .collect(),
                positions: (0..len as i32).collect(),
                kv,
            }
        };
        // capacity around ~4 dense entries of len 48: constant eviction
        // pressure, pins meeting the evictor, frequent re-elections
        let probe = mk_dense(48, 0);
        let cap = (probe.kv.bytes() + 48 * 8) * 4 + rng.below(4096);
        let mut st = CacheStore::new(&sp, cap);
        let nk = 12;
        for _ in 0..rng.range(30, 80) {
            let i = rng.below(nk);
            let k = mk_key(i);
            match rng.below(4) {
                0 | 1 => {
                    let len = 16 * rng.range(1, 5); // 16..64
                    // oversize inserts are legal input: the store must
                    // reject them, not overcommit
                    let _ = st.put_dense(k, mk_dense(len, rng.below(1 << 20) as u32));
                }
                2 => {
                    // mirror a resident dense entry, if any
                    let mkey = mk_key(rng.below(nk));
                    let master = match st.get(&mkey) {
                        Some(Fetched::Dense(d)) => {
                            Some((d.tokens.clone(), d.kv.clone()))
                        }
                        _ => None,
                    };
                    if let Some((toks, mkv)) = master {
                        if k != mkey {
                            let len = toks.len();
                            let mut kv2 = mkv.clone();
                            let o = kv2.off(0, rng.below(len));
                            kv2.k[o] += 7.0;
                            let d = diff_blocks(&mkv, &kv2, len, bt);
                            let d = identity_aligned(
                                d, len.div_ceil(bt), len,
                            );
                            let _ = st.put_mirror(
                                k,
                                MirrorEntry {
                                    master: mkey,
                                    tokens: toks,
                                    positions: (0..len as i32).collect(),
                                    diff: d,
                                },
                            );
                        }
                    }
                }
                _ => {
                    // a resident mirror always resolves: its master is
                    // resident and dense (the no-orphan invariant)
                    let resident = st.contains(&k);
                    match st.get(&k) {
                        Some(Fetched::Mirror(h)) => {
                            assert_eq!(
                                h.master.kv.seq,
                                h.master.tokens.len()
                            );
                        }
                        Some(Fetched::Dense(_)) => {}
                        None => assert!(!resident, "resident key missed"),
                    }
                }
            }
            // after every op: ledger balances, LRU chain is exact, no
            // dangling master refs, capacity honored
            st.assert_invariants();
        }
    });
}

#[test]
// Disk-bound (spill files round-trip through temp_dir); interpreted
// file I/O makes this prohibitively slow under miri.
#[cfg_attr(miri, ignore)]
fn prop_tiered_store_churn_preserves_invariants() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    forall(30, |rng| {
        let sp = spec();
        let bt = sp.block_tokens;
        let case = CASE.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "td-prop-tier-{}-{case}",
            std::process::id()
        ));
        let mk_key = |i: usize| StoreKey {
            content: i as u64,
            role: if i % 2 == 0 {
                Role::Segment
            } else {
                Role::AgentCache { agent: i }
            },
        };
        let mk_dense = |len: usize, salt: u32| {
            let mut kv = KvBuf::zeroed(sp.n_layers, len, sp.d_model);
            for (i, x) in kv.k.iter_mut().enumerate() {
                *x = ((i as u32) ^ salt) as f32 / 100.0;
            }
            DenseEntry {
                tokens: (0..len as u32)
                    .map(|i| 4 + ((i ^ salt) % 200))
                    .collect(),
                positions: (0..len as i32).collect(),
                kv,
            }
        };
        // hot capacity around ~2 dense entries: every insert spills, so
        // restores, cold evictions, and re-elections over cold mirrors
        // all fire; a sometimes-tiny cold tier exercises cold rejection
        // (evicted-to-nothing) and cold LRU eviction too
        let probe = mk_dense(48, 0);
        let eb = probe.kv.bytes() + 48 * 8;
        let cap = eb * 2 + rng.below(4096);
        let cold_cap = eb * rng.range(1, 8);
        let mut st = CacheStore::new(&sp, cap);
        st.configure_tier(TierConfig {
            cold_bytes: cold_cap,
            spill_dir: dir.clone(),
            quantize: rng.below(2) == 0,
            format: if rng.below(2) == 0 {
                QuantFormat::Int8
            } else {
                QuantFormat::Q4
            },
            fault_plan: None,
            recover: false,
        })
        .unwrap();
        let nk = 12;
        let mut round = 0u64;
        st.note_round(round);
        for _ in 0..rng.range(30, 80) {
            let i = rng.below(nk);
            let k = mk_key(i);
            match rng.below(6) {
                0 | 1 => {
                    // re-puts over master keys force re-election while
                    // their mirrors may sit spilled in the cold tier
                    let len = 16 * rng.range(1, 5); // 16..64
                    let _ = st.put_dense(
                        k,
                        mk_dense(len, rng.below(1 << 20) as u32),
                    );
                }
                2 => {
                    let mkey = mk_key(rng.below(nk));
                    let master = match st.get(&mkey) {
                        Some(Fetched::Dense(d)) => {
                            Some((d.tokens.clone(), d.kv.clone()))
                        }
                        _ => None,
                    };
                    if let Some((toks, mkv)) = master {
                        if k != mkey {
                            let len = toks.len();
                            let mut kv2 = mkv.clone();
                            let o = kv2.off(0, rng.below(len));
                            kv2.k[o] += 7.0;
                            let d = diff_blocks(&mkv, &kv2, len, bt);
                            let d = identity_aligned(
                                d, len.div_ceil(bt), len,
                            );
                            let _ = st.put_mirror(
                                k,
                                MirrorEntry {
                                    master: mkey,
                                    tokens: toks,
                                    positions: (0..len as i32).collect(),
                                    diff: d,
                                },
                            );
                        }
                    }
                }
                3 => {
                    // scheduler feed: hint a next use, sometimes tick
                    // the round clock forward
                    st.hint_next_use(
                        &k,
                        round + 1 + rng.below(3) as u64,
                    );
                    if rng.below(2) == 0 {
                        round += 1;
                        st.note_round(round);
                    }
                }
                4 => {
                    // round-aware prefetch over a random key subset
                    let keys: Vec<StoreKey> = (0..nk)
                        .filter(|_| rng.below(3) == 0)
                        .map(mk_key)
                        .collect();
                    st.prefetch(&keys);
                }
                _ => {
                    // a hot-resident key always hits; a spilled key may
                    // legally miss (restore that cannot fit re-spills)
                    let resident = st.contains(&k);
                    match st.get(&k) {
                        Some(Fetched::Mirror(h)) => {
                            assert_eq!(
                                h.master.kv.seq,
                                h.master.tokens.len()
                            );
                        }
                        Some(Fetched::Dense(_)) => {}
                        None => assert!(!resident, "resident key missed"),
                    }
                }
            }
            // hot + cold ledgers exact, both capacities honored, the
            // tiers disjoint, every cold mirror's master chain intact
            st.assert_invariants();
            assert!(st.bytes() <= cap, "hot over budget");
            assert!(st.cold_bytes() <= cold_cap, "cold over budget");
        }
        drop(st);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
// Disk-bound (spill files + crash/recovery scans through temp_dir);
// interpreted file I/O makes this prohibitively slow under miri.
#[cfg_attr(miri, ignore)]
fn prop_tiered_crash_recovery_churn_preserves_invariants() {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    forall(25, |rng| {
        let sp = spec();
        let bt = sp.block_tokens;
        let case = CASE.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "td-prop-recover-{}-{case}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mk_key = |i: usize| StoreKey {
            content: i as u64,
            role: if i % 2 == 0 {
                Role::Segment
            } else {
                Role::AgentCache { agent: i }
            },
        };
        let mk_dense = |len: usize, salt: u32| {
            let mut kv = KvBuf::zeroed(sp.n_layers, len, sp.d_model);
            for (i, x) in kv.k.iter_mut().enumerate() {
                *x = ((i as u32) ^ salt) as f32 / 100.0;
            }
            DenseEntry {
                tokens: (0..len as u32)
                    .map(|i| 4 + ((i ^ salt) % 200))
                    .collect(),
                positions: (0..len as i32).collect(),
                kv,
            }
        };
        // hot capacity ~2 entries so puts spill constantly; exact
        // (unquantized) payloads so a surviving entry is bitwise
        let probe = mk_dense(48, 0);
        let eb = probe.kv.bytes() + 48 * 8;
        let cap = eb * 2 + rng.below(4096);
        let cold_cap = eb * rng.range(3, 8);
        let mk_store = |sp: &ModelSpec| {
            let mut st = CacheStore::new(sp, cap);
            st.configure_tier(TierConfig {
                cold_bytes: cold_cap,
                spill_dir: dir.clone(),
                quantize: false,
                format: QuantFormat::Int8,
                fault_plan: None,
                recover: true,
            })
            .unwrap();
            st
        };
        let mut st = mk_store(&sp);
        // content ledger: the tokens last stored at each key — every hit
        // (hot or restored) must reproduce them, across any crash
        let mut ledger: HashMap<StoreKey, Vec<u32>> = HashMap::new();
        let nk = 10;
        for _ in 0..rng.range(25, 60) {
            let i = rng.below(nk);
            let k = mk_key(i);
            match rng.below(8) {
                0 | 1 | 2 => {
                    let len = 16 * rng.range(1, 5); // 16..64
                    let e = mk_dense(len, rng.below(1 << 20) as u32);
                    let toks = e.tokens.clone();
                    if st.put_dense(k, e).is_ok() {
                        ledger.insert(k, toks);
                    }
                }
                3 => {
                    let mkey = mk_key(rng.below(nk));
                    let master = match st.get(&mkey) {
                        Some(Fetched::Dense(d)) => {
                            Some((d.tokens.clone(), d.kv.clone()))
                        }
                        _ => None,
                    };
                    if let Some((toks, mkv)) = master {
                        if k != mkey {
                            let len = toks.len();
                            let mut kv2 = mkv.clone();
                            let o = kv2.off(0, rng.below(len));
                            kv2.k[o] += 7.0;
                            let d = diff_blocks(&mkv, &kv2, len, bt);
                            let d = identity_aligned(
                                d, len.div_ceil(bt), len,
                            );
                            if st
                                .put_mirror(
                                    k,
                                    MirrorEntry {
                                        master: mkey,
                                        tokens: toks.clone(),
                                        positions: (0..len as i32)
                                            .collect(),
                                        diff: d,
                                    },
                                )
                                .is_ok()
                            {
                                ledger.insert(k, toks);
                            }
                        }
                    }
                }
                4 => {
                    // CRASH: no destructor runs, no cleanup happens —
                    // then a new store recovers the cold index from
                    // whatever spill files survived on disk
                    std::mem::forget(std::mem::replace(
                        &mut st,
                        mk_store(&sp),
                    ));
                    // hot-resident entries died with the process; any
                    // key the recovered index still serves must match
                    // the ledger (checked by the get arm below)
                    assert!(
                        st.cold_bytes() <= cold_cap,
                        "recovery overfilled the cold tier"
                    );
                }
                5 => {
                    let keys: Vec<StoreKey> = (0..nk)
                        .filter(|_| rng.below(3) == 0)
                        .map(mk_key)
                        .collect();
                    st.prefetch(&keys);
                }
                _ => {
                    // a hit — hot, restored, or recovered-then-restored
                    // — must reproduce exactly the tokens last stored
                    let resident = st.contains(&k);
                    match st.get(&k) {
                        Some(Fetched::Dense(d)) => {
                            if let Some(toks) = ledger.get(&k) {
                                assert_eq!(
                                    &d.tokens, toks,
                                    "dense hit diverged from ledger"
                                );
                            }
                        }
                        Some(Fetched::Mirror(h)) => {
                            assert_eq!(
                                h.master.kv.seq,
                                h.master.tokens.len()
                            );
                            if let Some(toks) = ledger.get(&k) {
                                assert_eq!(
                                    &h.mirror.tokens, toks,
                                    "mirror hit diverged from ledger"
                                );
                            }
                        }
                        None => assert!(!resident, "resident key missed"),
                    }
                }
            }
            st.assert_invariants();
            assert!(st.bytes() <= cap, "hot over budget");
            assert!(st.cold_bytes() <= cold_cap, "cold over budget");
        }
        // a torn in-flight write + one corrupted spill file, then a
        // final crash/recover: recovery must quarantine both, keep the
        // rest, and leave a store whose hits still match the ledger
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("spill-77777.tdm.tmp"), b"torn").unwrap();
        let victim = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.path())
            .find(|p| {
                p.extension().is_some_and(|x| x == "tdm")
                    && std::fs::metadata(p)
                        .is_ok_and(|m| m.len() > 12)
            });
        if let Some(p) = &victim {
            let mut buf = std::fs::read(p).unwrap();
            let mid = buf.len() / 2;
            buf[mid] ^= 0x20;
            std::fs::write(p, &buf).unwrap();
        }
        std::mem::forget(std::mem::replace(&mut st, mk_store(&sp)));
        let c = st.counters();
        assert!(
            c.quarantined >= 1 + u64::from(victim.is_some()),
            "torn + corrupt files must be quarantined: {c:?}"
        );
        st.assert_invariants();
        for i in 0..nk {
            let k = mk_key(i);
            if let Some(Fetched::Dense(d)) = st.get(&k) {
                if let Some(toks) = ledger.get(&k) {
                    assert_eq!(&d.tokens, toks);
                }
            }
            st.assert_invariants();
        }
        drop(st);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

// ---------------------------------------------------------------------
// importance selection
// ---------------------------------------------------------------------

#[test]
fn prop_block_selection_invariants() {
    forall(150, |rng| {
        let bt = 16;
        let len = rng.range(1, 512);
        let mut scores = vec![0f32; len];
        for s in scores.iter_mut() {
            *s = if rng.f64() < 0.2 {
                INVALID_SCORE
            } else {
                rng.f64() as f32
            };
        }
        let cfg = ImportanceConfig {
            recompute_frac: rng.f64() * 0.5,
            min_recompute: rng.below(32),
        };
        let sel = select_important_blocks(&scores, len, bt, &cfg);
        // sorted unique, in range, last position present
        assert!(sel.windows(2).all(|w| w[0] < w[1]));
        assert!(sel.iter().all(|&i| (i as usize) < len));
        assert!(sel.contains(&((len - 1) as i32)));
        // every invalid position is selected
        for (i, &s) in scores.iter().enumerate() {
            if s >= INVALID_SCORE {
                assert!(sel.contains(&(i as i32)), "invalid {i} unselected");
            }
        }
        // block-clustered: selected positions cover whole blocks
        for &i in &sel {
            let b = i as usize / bt;
            let lo = b * bt;
            let hi = ((b + 1) * bt).min(len);
            for j in lo..hi {
                assert!(sel.contains(&(j as i32)));
            }
        }
    });
}

// ---------------------------------------------------------------------
// collector + engine
// ---------------------------------------------------------------------

#[test]
// Full mock forward passes (two engines per case) — too slow under
// miri's interpreter; the store/diff layers it exercises are covered
// by the miri-enabled store proptests above.
#[cfg_attr(miri, ignore)]
fn prop_collective_equals_serial() {
    let rt = MockRuntime::new();
    forall(25, |rng| {
        let sp = rt.spec("sim-7b").unwrap().clone();
        let s = sp.max_seq;
        let n = rng.range(2, 6);
        let len = rng.range(8, 128);
        let toks: Vec<u32> =
            (0..len).map(|_| 4 + rng.below(200) as u32).collect();
        let pre = rt.prefill("sim-7b", &toks, len).unwrap();
        let mk = |id: u64| {
            let mut tokens = toks.clone();
            tokens.resize(s, 0);
            let mut kv = KvBuf::for_spec(&sp);
            kv.copy_rows_from(&pre.kv, 0, 0, len);
            let mut valid = vec![0u8; s];
            valid[..len].iter_mut().for_each(|x| *x = 1);
            ReuseTask {
                id,
                tokens,
                valid_len: len,
                old_pos: (0..s as i32).collect(),
                valid,
                kv,
            }
        };
        let t1: Vec<ReuseTask> = (0..n as u64).map(mk).collect();
        let t2: Vec<ReuseTask> = (0..n as u64).map(mk).collect();
        let (rc, _) = run_reuse(
            &rt,
            "sim-7b",
            &t1,
            &CollectorConfig { collective: true, ..Default::default() },
        )
        .unwrap();
        let (rs, _) = run_reuse(
            &rt,
            "sim-7b",
            &t2,
            &CollectorConfig { collective: false, ..Default::default() },
        )
        .unwrap();
        for (a, b) in rc.iter().zip(&rs) {
            assert_eq!(a.kv, b.kv);
            assert_eq!(a.logits, b.logits);
        }
    });
}

#[test]
// End-to-end engine rounds (prefill + decode over every policy): far
// too slow under miri's interpreter.
#[cfg_attr(miri, ignore)]
fn prop_engine_serves_random_round_shapes() {
    forall(15, |rng| {
        let policy = match rng.below(4) {
            0 => Policy::VllmPrefix,
            1 => Policy::CacheBlendOrdinary,
            2 => Policy::CacheBlendFull,
            _ => Policy::TokenDance,
        };
        let mut eng = Engine::builder("sim-7b")
            .policy(policy)
            .pool_blocks(512)
            .mock()
            .build()
            .unwrap();
        let agents = rng.range(1, 6);
        let rounds = rng.range(1, 4);
        let mut shared: Vec<Vec<u32>> = Vec::new();
        for round in 0..rounds {
            let mut sub = RoundSubmission::new(round);
            for a in 0..agents {
                let mut p = RoundAwarePrompt::new();
                p.push(
                    BlockKind::PrivateHistory,
                    encode(&format!("agent {a} h{}", rng.below(1000))),
                );
                for (i, toks) in shared.iter().enumerate() {
                    p.push(
                        BlockKind::SharedOutput { producer: i, round },
                        toks.clone(),
                    );
                }
                p.push(BlockKind::RoundTask, encode("go"));
                p.pad_blocks(16, 36);
                sub.push(AgentRequest {
                    agent: a,
                    round,
                    prompt: p,
                    max_new_tokens: rng.range(1, 16),
                    retain: true,
                });
            }
            eng.submit_round(sub).unwrap();
            let done = eng.drain().unwrap();
            assert_eq!(done.len(), agents, "{policy:?} must complete");
            shared = done.iter().map(|c| c.generated.clone()).collect();
        }
        assert_eq!(eng.pending_count(), 0);
    });
}

#[test]
// The worker-pool transparency property over *random* cohort shapes
// (the golden grid pins fixed ones): the parallel assembly/encode waves
// must merge in exactly the serial order — same completion order, same
// token streams, same logical counters (the expectation-memo counters
// would move if the per-signature pre-build wave ever double-built or
// reordered a signature group against the serial BTreeMap-driven walk).
// Engine rounds are too slow under miri's interpreter.
#[cfg_attr(miri, ignore)]
fn prop_worker_pool_is_transparent() {
    forall(10, |rng| {
        let policy = match rng.below(4) {
            0 => Policy::VllmPrefix,
            1 => Policy::CacheBlendOrdinary,
            2 => Policy::CacheBlendFull,
            _ => Policy::TokenDance,
        };
        let agents = rng.range(2, 7);
        let rounds = rng.range(1, 4);
        // one fixed prompt script, replayed against both engines
        let mut script: Vec<Vec<(Vec<u32>, usize)>> = Vec::new();
        for _ in 0..rounds {
            script.push(
                (0..agents)
                    .map(|a| {
                        (
                            encode(&format!(
                                "agent {a} h{}",
                                rng.below(1000)
                            )),
                            rng.range(1, 16),
                        )
                    })
                    .collect(),
            );
        }
        let run = |workers: usize| {
            let mut eng = Engine::builder("sim-7b")
                .policy(policy)
                .pool_blocks(512)
                .workers(workers)
                .mock()
                .build()
                .unwrap();
            let mut transcript: Vec<(u64, usize, Vec<u32>)> = Vec::new();
            let mut shared: Vec<Vec<u32>> = Vec::new();
            for (round, specs) in script.iter().enumerate() {
                let mut sub = RoundSubmission::new(round);
                for (a, (hist, max_new)) in specs.iter().enumerate() {
                    let mut p = RoundAwarePrompt::new();
                    p.push(BlockKind::PrivateHistory, hist.clone());
                    for (i, toks) in shared.iter().enumerate() {
                        p.push(
                            BlockKind::SharedOutput { producer: i, round },
                            toks.clone(),
                        );
                    }
                    p.push(BlockKind::RoundTask, encode("go"));
                    p.pad_blocks(16, 36);
                    sub.push(AgentRequest {
                        agent: a,
                        round,
                        prompt: p,
                        max_new_tokens: *max_new,
                        retain: true,
                    });
                }
                eng.submit_round(sub).unwrap();
                let done = eng.drain().unwrap();
                assert_eq!(done.len(), agents);
                shared =
                    done.iter().map(|c| c.generated.clone()).collect();
                for c in &done {
                    transcript.push((c.id, c.agent, c.generated.clone()));
                }
            }
            let m = &eng.metrics;
            let counters = (
                m.assembly_lookups,
                m.assembly_dedup_hits,
                m.assembly_restores,
                m.prefill_reused,
                m.prefill_full,
                m.encode_lookups,
                m.expected_memo_hits,
                m.encode_skipped_blocks,
                m.encode_rope_recovers,
            );
            (transcript, counters)
        };
        let (t1, c1) = run(1);
        let (t4, c4) = run(4);
        assert_eq!(t1, t4, "{policy:?}: token streams moved with workers");
        assert_eq!(c1, c4, "{policy:?}: logical counters moved with workers");
    });
}

#[test]
// The per-request fault-isolation property over *random* fault plans
// (the chaos experiment pins fixed arms): under any mix of persistent,
// transient, and straggler compute faults, the surviving agents' token
// streams must be bitwise identical to a fault-free run restricted to
// the same survivor set — a fault removes its victim from the round,
// never perturbs a cohort-mate. Valid for transitively-closed
// topologies (Full, Teams): a failed request writes nothing (donor
// extraction happens only at finalize), so survivors see identical
// store bytes and reuse elections either way. Engine rounds are too
// slow under miri's interpreter.
#[cfg_attr(miri, ignore)]
fn prop_survivors_unperturbed_by_injected_faults() {
    use std::collections::BTreeSet;
    use tokendance::runtime::RuntimeFaultPlan;
    use tokendance::serve::EngineEvent;
    use tokendance::workload::{Session, Topology, WorkloadConfig};

    type Streams = Vec<(usize, usize, Vec<u32>)>;
    type FailSet = BTreeSet<(usize, usize)>;

    // Drive one session, skipping `(round, agent)` pairs in `skip` at
    // submission time (the oracle passes the faulted run's fail set).
    fn run(
        agents: usize,
        rounds: usize,
        topology: Topology,
        plan: Option<RuntimeFaultPlan>,
        skip: &FailSet,
    ) -> (Streams, FailSet) {
        let mut b = Engine::builder("sim-7b")
            .policy(Policy::TokenDance)
            .pool_blocks(512)
            .mock();
        if let Some(p) = plan {
            b = b.runtime_fault_plan(p);
        }
        let mut eng = b.build().unwrap();
        let mut session = Session::new(
            WorkloadConfig::generative_agents(1, agents, rounds)
                .with_topology(topology),
            0,
        );
        let mut streams: Streams = Vec::new();
        let mut fails = FailSet::new();
        while !session.done() {
            let round = session.global_round();
            let reqs: Vec<_> = session
                .next_round()
                .into_iter()
                .filter(|r| !skip.contains(&(round, r.agent)))
                .collect();
            let outs: Vec<(usize, Vec<u32>)> = if reqs.is_empty() {
                Vec::new()
            } else {
                eng.submit_round(
                    RoundSubmission::new(round).requests(reqs),
                )
                .unwrap();
                eng.drain()
                    .unwrap()
                    .iter()
                    .map(|c| (c.agent, c.generated.clone()))
                    .collect()
            };
            for ev in eng.poll_events() {
                if let EngineEvent::Failed { round, agent, .. }
                | EngineEvent::Shed { round, agent, .. } = ev
                {
                    fails.insert((round, agent));
                }
            }
            for (agent, toks) in &outs {
                streams.push((round, *agent, toks.clone()));
            }
            session.absorb(&outs).unwrap();
        }
        streams.sort();
        (streams, fails)
    }

    forall(8, |rng| {
        let agents = rng.range(3, 6);
        let rounds = rng.range(2, 4);
        let topology = if rng.below(2) == 0 {
            Topology::Full
        } else {
            Topology::Teams { size: 2 }
        };
        let plan = RuntimeFaultPlan {
            prefill_fail: rng.f64() * 0.2,
            decode_fail: rng.f64() * 0.1,
            group_fail: rng.f64() * 0.2,
            transient: rng.f64(),
            slow: rng.f64() * 0.2,
            slow_steps: rng.below(4) as u64,
            ..RuntimeFaultPlan::quiet(rng.below(1 << 30) as u64)
        };
        let (faulted, fails) =
            run(agents, rounds, topology, Some(plan), &FailSet::new());
        let (oracle, oracle_fails) =
            run(agents, rounds, topology, None, &fails);
        assert!(
            oracle_fails.is_empty(),
            "fault-free oracle reported failures"
        );
        assert_eq!(
            faulted, oracle,
            "survivor streams perturbed by injected faults \
             ({topology:?}, {plan:?})"
        );
    });
}

#[test]
fn prop_buckets_fit_monotone() {
    let b = Buckets::default();
    forall(200, |rng| {
        let n = rng.range(1, 600);
        if let Some(f) = Buckets::fit(&b.prefill_t, n) {
            assert!(f >= n);
            // minimality: no smaller bucket fits
            for &x in &b.prefill_t {
                if x >= n {
                    assert!(f <= x);
                }
            }
        } else {
            assert!(n > *b.prefill_t.last().unwrap());
        }
    });
}
