//! Deterministic golden-run harness: a fixed-seed 3-round session per
//! policy × topology on the mock runtime, digested into one u64 per
//! config over every generated token stream plus the key logical
//! counters (store lookups, gather-plan dedup hits, mirror restores,
//! cohort formation, store hits/misses/evictions/promotions, and the
//! round-end encode counters — expectation-memo traffic, provenance-
//! skipped blocks, rope passes). Wall-clock metrics are deliberately
//! excluded — everything digested is logical and must be bit-stable
//! across runs and machines.
//!
//! Two layers of protection:
//!
//! * [`golden_runs_are_deterministic_in_process`] runs every config
//!   twice with fresh engines and requires identical digests — any
//!   nondeterminism (hash-map iteration order leaking into behavior,
//!   uninitialized buffer reads, time-dependent control flow) fails
//!   tier-1 immediately.
//! * [`golden_run_digests_match_pinned`] compares against the pinned
//!   digest file `rust/tests/golden/digests.txt` — once that file is
//!   committed, any *silent behavior change* fails tier-1. The file is
//!   written on first run (this build container has no Rust toolchain
//!   to pre-compute it), and CI runs the test suite twice back to back
//!   so the second invocation always verifies against the first. Until
//!   the file is committed the pin only covers same-workspace
//!   invocations, so CI emits a warning annotation on every run and
//!   uploads the generated file as the `golden-digests` artifact for a
//!   maintainer to commit. Regenerate deliberately with
//!   `GOLDEN_BLESS=1 cargo test --test golden_runs`.

use std::fmt::Write as _;
use std::path::Path;

use tokendance::engine::{Engine, Policy};
use tokendance::serve::RoundSubmission;
use tokendance::util::fnv1a;
use tokendance::workload::{Session, Topology, WorkloadConfig};

const AGENTS: usize = 4;
const ROUNDS: usize = 3;

/// The golden grid: every policy × a representative topology per class.
fn configs() -> Vec<(Policy, Topology)> {
    let mut out = Vec::new();
    for policy in Policy::all() {
        for topology in [
            Topology::Full,
            Topology::Neighborhood { k: 1 },
            Topology::Teams { size: 2 },
        ] {
            out.push((policy, topology));
        }
    }
    out
}

/// Drive one fixed-seed session and return (transcript, digest). The
/// transcript covers every output token of every agent in every round
/// plus the logical counters, so any behavior change moves the digest.
/// Builder default worker count (1, or `TOKENDANCE_WORKERS` — CI runs
/// the suite at both): the digests must not move either way.
fn run_config(policy: Policy, topology: Topology) -> (String, u64) {
    run_config_with(policy, topology, None)
}

fn run_config_with(
    policy: Policy,
    topology: Topology,
    workers: Option<usize>,
) -> (String, u64) {
    let mut b = Engine::builder("sim-7b")
        .policy(policy)
        .pool_blocks(1024)
        .mock();
    if let Some(w) = workers {
        b = b.workers(w);
    }
    let mut eng = b.build().unwrap();
    let cfg = WorkloadConfig::generative_agents(1, AGENTS, ROUNDS)
        .with_topology(topology);
    let mut session = Session::new(cfg, 0);
    let mut t = String::new();
    while !session.done() {
        let sub = RoundSubmission::new(session.global_round())
            .requests(session.next_round());
        eng.submit_round(sub).unwrap();
        let mut outs: Vec<(usize, Vec<u32>)> = eng
            .drain()
            .unwrap()
            .iter()
            .map(|c| (c.agent, c.generated.clone()))
            .collect();
        outs.sort_by_key(|(a, _)| *a);
        for (a, toks) in &outs {
            writeln!(t, "r{} a{a} {toks:?}", session.round).unwrap();
        }
        session.absorb(&outs).unwrap();
    }
    let m = &eng.metrics;
    let c = eng.store().counters();
    writeln!(
        t,
        "lookups={} dedup={} restores={} reused={} full={} \
         cohorts={} singletons={} hits={} misses={} evictions={} \
         promotions={} rejections={}",
        m.assembly_lookups,
        m.assembly_dedup_hits,
        m.assembly_restores,
        m.prefill_reused,
        m.prefill_full,
        m.cohorts_collective,
        m.cohorts_singleton,
        c.hits,
        c.misses,
        c.evictions,
        c.promotions,
        c.rejected_inserts
    )
    .unwrap();
    // round-end encode counters: a provenance regression (silently
    // scanning everything, or skipping a genuinely dirty block and
    // thereby changing a mirror's diff) moves these and flips the pin
    writeln!(
        t,
        "enc_lookups={} enc_memo_hits={} enc_skipped={} enc_ropes={}",
        m.encode_lookups,
        m.expected_memo_hits,
        m.encode_skipped_blocks,
        m.encode_rope_recovers
    )
    .unwrap();
    let digest = fnv1a(t.as_bytes());
    (t, digest)
}

#[test]
fn golden_runs_are_deterministic_in_process() {
    for (policy, topology) in configs() {
        let (t1, d1) = run_config(policy, topology);
        let (t2, d2) = run_config(policy, topology);
        assert_eq!(
            d1,
            d2,
            "{policy:?}/{} nondeterministic between two fresh engines:\n\
             --- first ---\n{t1}\n--- second ---\n{t2}",
            topology.label()
        );
    }
}

/// The worker-pool determinism guarantee, pinned directly: the engine's
/// parallel sections (cohort assembly, mirror materialization, encode
/// expectation pre-builds) must produce byte-identical transcripts and
/// logical counters at any worker count. `workers(1)` is the serial
/// reference; `workers(4)` exercises every fan-out with multiple scoped
/// threads and multiple scratch arenas.
#[test]
fn digests_are_worker_count_invariant() {
    for (policy, topology) in configs() {
        let (t1, d1) = run_config_with(policy, topology, Some(1));
        let (t4, d4) = run_config_with(policy, topology, Some(4));
        assert_eq!(
            d1,
            d4,
            "{policy:?}/{} diverges between workers=1 and workers=4:\n\
             --- serial ---\n{t1}\n--- 4 workers ---\n{t4}",
            topology.label()
        );
    }
}

#[test]
fn golden_run_digests_match_pinned() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/digests.txt");
    let mut current = String::from(
        "# golden-run digests: one fixed-seed 3-round session per\n\
         # policy x topology on the mock runtime (see golden_runs.rs).\n\
         # Regenerate deliberately with:\n\
         #   GOLDEN_BLESS=1 cargo test --test golden_runs\n",
    );
    for (policy, topology) in configs() {
        let (_, d) = run_config(policy, topology);
        writeln!(current, "{policy:?} {} {d:016x}", topology.label())
            .unwrap();
    }
    let bless = std::env::var("GOLDEN_BLESS").is_ok();
    match std::fs::read_to_string(&path) {
        Ok(pinned) if !bless => {
            assert_eq!(
                pinned, current,
                "golden digests changed. If the behavior change is \
                 intentional, regenerate with `GOLDEN_BLESS=1 cargo test \
                 --test golden_runs` and commit the updated \
                 rust/tests/golden/digests.txt; otherwise this is a \
                 silent behavior regression."
            );
        }
        _ => {
            // first run (no pinned file yet) or explicit bless: write the
            // digests so the next invocation verifies against them
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &current).unwrap();
            eprintln!(
                "golden_runs: wrote {} ({}); commit it to pin digests",
                path.display(),
                if bless { "GOLDEN_BLESS=1" } else { "first run" }
            );
        }
    }
}
